//! Paper Fig. 3 (in-hindsight hardware framework), realized twice over:
//!
//! 1. the **fused single-pass kernel** `quant::kernel::minmax_fq` — one
//!    traversal computes the online accumulator statistics *and*
//!    requantizes with the static range, vs the scalar two-pass
//!    `minmax` + `fake_quant_slice` baseline it replaced — timed **per
//!    kernel backend** (scalar reference / lane-chunked SIMD /
//!    `std::thread` chunked-parallel; all bit-identical, so the table
//!    is purely a speed ladder), plus the per-channel axis
//!    (`minmax_fq_axis` vs the scalar gather-per-channel reference).
//!    Runs without artifacts; the numbers append to
//!    `BENCH_kernels.json` — one record per (size, backend) with a
//!    `backend` field, and one `dispatch: true` record timing the
//!    process-wide dispatched entry point (whatever `--kernel-backend`
//!    / `HINDSIGHT_KERNEL_BACKEND` resolved to), so CI can assert the
//!    env-selected backend was really exercised.
//! 2. the **runtime contract**: static ranges go into the executable,
//!    online statistics come back out of the same execution, and the
//!    between-step update is a handful of flops in the coordinator
//!    (needs built artifacts; skipped otherwise).
//!
//!   cargo bench --bench fig3_online_stats
//!   HINDSIGHT_KERNEL_BACKEND=simd cargo bench --bench fig3_online_stats

use std::time::Instant;

use hindsight::coordinator::{Estimator, TrainConfig, Trainer};
use hindsight::quant::{self, kernel};
use hindsight::quant::kernel::KernelBackend;
use hindsight::runtime::manifest::Manifest;
use hindsight::runtime::Engine;
use hindsight::util::bench::{append_bench_record, quick, time_it, Table};
use hindsight::util::json::Value;
use hindsight::util::rng::Pcg32;

fn kernel_section() {
    let mut table = Table::new(
        "Fig. 3 kernel — fused minmax+fake-quant per backend vs scalar two-pass",
        &["elems", "backend", "scalar ms", "fused ms", "speedup"],
    );
    let iters = if quick() { 5 } else { 30 };
    for n in [65_536usize, 1_048_576, 4_194_304] {
        let mut rng = Pcg32::new(n as u64, 7);
        let src: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        // hindsight-style preset range (slightly stale extrema)
        let (qlo, qhi) = (-3.0f32, 3.0);
        // fake-quant is idempotent on on-grid values, so re-running on
        // the same buffer costs the same as the first pass — no per-iter
        // copies polluting the timing
        let mut buf = src.clone();
        let scalar = time_it("scalar", 2, iters, || {
            let stats = quant::minmax(&buf);
            std::hint::black_box(stats);
            quant::fake_quant_slice(&mut buf, qlo, qhi, 8);
            std::hint::black_box(buf.first());
        });
        for b in KernelBackend::ALL {
            let mut buf2 = src.clone();
            let fused = time_it(b.key(), 2, iters, || {
                let stats = kernel::minmax_fq_on(b, &mut buf2, qlo, qhi, 8);
                std::hint::black_box(stats);
                std::hint::black_box(buf2.first());
            });
            let speedup = scalar.mean_s / fused.mean_s;
            table.row(&[
                n.to_string(),
                b.key().to_string(),
                format!("{:.3}", scalar.mean_ms()),
                format!("{:.3}", fused.mean_ms()),
                format!("{speedup:.2}x"),
            ]);
            let rec = Value::object(vec![
                ("bench", Value::from("fig3_online_stats")),
                ("kernel", Value::from("minmax_fq")),
                ("backend", Value::from(b.key())),
                ("elems", Value::from(n)),
                ("bits", Value::from(8usize)),
                ("iters", Value::from(iters)),
                ("scalar_ms", Value::from(scalar.mean_ms())),
                ("fused_ms", Value::from(fused.mean_ms())),
                ("speedup", Value::from(speedup)),
            ]);
            match append_bench_record(rec) {
                Ok(path) => println!("recorded {} elems [{}] -> {}", n, b.key(), path.display()),
                Err(e) => eprintln!("could not record bench json: {e}"),
            }
        }
    }
    table.print();
}

/// Time the *dispatched* entry point — whatever backend the process
/// resolved (CLI > env > auto) — and record it with `dispatch: true`,
/// so a sweep's hot path is provably running on the selected backend.
fn dispatch_section() {
    let active = kernel::backend();
    let n = 1_048_576usize;
    let iters = if quick() { 5 } else { 30 };
    let mut rng = Pcg32::new(n as u64, 11);
    let mut buf: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
    let fused = time_it("dispatched", 2, iters, || {
        let stats = kernel::minmax_fq(&mut buf, -3.0, 3.0, 8);
        std::hint::black_box(stats);
        std::hint::black_box(buf.first());
    });
    println!(
        "dispatched minmax_fq ({} elems) on backend '{active}': {:.3} ms",
        n,
        fused.mean_ms()
    );
    let rec = Value::object(vec![
        ("bench", Value::from("fig3_online_stats")),
        ("kernel", Value::from("minmax_fq")),
        ("dispatch", Value::Bool(true)),
        ("backend", Value::from(active.key())),
        ("elems", Value::from(n)),
        ("bits", Value::from(8usize)),
        ("iters", Value::from(iters)),
        ("fused_ms", Value::from(fused.mean_ms())),
    ]);
    match append_bench_record(rec) {
        Ok(path) => println!("recorded dispatch [{}] -> {}", active.key(), path.display()),
        Err(e) => eprintln!("could not record bench json: {e}"),
    }
}

/// Per-channel axis of the same Fig. 3 contract: one channel-strided
/// fused traversal (`minmax_fq_axis`, per backend) vs the scalar
/// per-channel reference (gather each channel, two passes, scatter
/// back), with the per-tensor `minmax_fq` timing alongside as the
/// granularity axis.
fn axis_kernel_section() {
    let mut table = Table::new(
        "Fig. 3 kernel, per-channel — fused minmax_fq_axis per backend vs scalar gather",
        &["elems", "channels", "backend", "scalar ms", "fused ms", "speedup", "per-tensor ms"],
    );
    let iters = if quick() { 5 } else { 30 };
    let channels = 64usize;
    for n in [65_536usize, 1_048_576, 4_194_304] {
        let mut rng = Pcg32::new(n as u64, 9);
        let src: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        let ranges: Vec<[f32; 2]> = (0..channels)
            .map(|c| {
                let w = 2.5 + (c % 7) as f32 * 0.2;
                [-w, w]
            })
            .collect();
        // scalar per-channel reference: strided gather, two passes per
        // channel, scatter back (what a non-fused coordinator would do)
        let mut buf = src.clone();
        let scalar = time_it("scalar-axis", 2, iters, || {
            for (c, r) in ranges.iter().enumerate() {
                let mut chan: Vec<f32> =
                    buf.iter().skip(c).step_by(channels).copied().collect();
                std::hint::black_box(quant::minmax(&chan));
                quant::fake_quant_slice(&mut chan, r[0], r[1], 8);
                for (k, v) in chan.iter().enumerate() {
                    buf[c + k * channels] = *v;
                }
            }
            std::hint::black_box(buf.first());
        });
        for b in KernelBackend::ALL {
            let mut buf2 = src.clone();
            let fused = time_it("fused-axis", 2, iters, || {
                let stats = kernel::minmax_fq_axis_on(b, &mut buf2, &ranges, 8);
                std::hint::black_box(stats.first().copied());
                std::hint::black_box(buf2.first());
            });
            // the granularity axis: same tensor through the per-tensor kernel
            let mut buf3 = src.clone();
            let per_tensor = time_it("per-tensor", 2, iters, || {
                let stats = kernel::minmax_fq_on(b, &mut buf3, -3.0, 3.0, 8);
                std::hint::black_box(stats);
                std::hint::black_box(buf3.first());
            });
            let speedup = scalar.mean_s / fused.mean_s;
            table.row(&[
                n.to_string(),
                channels.to_string(),
                b.key().to_string(),
                format!("{:.3}", scalar.mean_ms()),
                format!("{:.3}", fused.mean_ms()),
                format!("{speedup:.2}x"),
                format!("{:.3}", per_tensor.mean_ms()),
            ]);
            let rec = Value::object(vec![
                ("bench", Value::from("fig3_online_stats")),
                ("kernel", Value::from("minmax_fq_axis")),
                ("backend", Value::from(b.key())),
                ("granularity", Value::from("per-channel")),
                ("elems", Value::from(n)),
                ("channels", Value::from(channels)),
                ("bits", Value::from(8usize)),
                ("iters", Value::from(iters)),
                ("scalar_ms", Value::from(scalar.mean_ms())),
                ("fused_ms", Value::from(fused.mean_ms())),
                ("speedup", Value::from(speedup)),
                ("per_tensor_ms", Value::from(per_tensor.mean_ms())),
            ]);
            match append_bench_record(rec) {
                Ok(path) => {
                    println!("recorded {} elems (axis) [{}] -> {}", n, b.key(), path.display())
                }
                Err(e) => eprintln!("could not record bench json: {e}"),
            }
        }
    }
    table.print();
}

/// Integer-payload stores: one fused traversal computes the stats,
/// quantizes to code indices and packs them into a `u8` payload
/// (`fq_store_i8`, nibble-packed `fq_store_i4`) — per backend vs the
/// scalar reference implementation of the *same* kernel.  Records carry
/// `payload: true` so the trajectory separates payload stores from the
/// fake-quant kernels above.
fn payload_section() {
    let mut table = Table::new(
        "Integer-payload stores — fq_store_i8 / fq_store_i4 per backend vs scalar",
        &["elems", "kernel", "backend", "scalar ms", "fused ms", "speedup"],
    );
    let iters = if quick() { 5 } else { 30 };
    for n in [65_536usize, 1_048_576, 4_194_304] {
        let mut rng = Pcg32::new(n as u64, 13);
        let src: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        for (kname, bits) in [("fq_store_i8", 8u32), ("fq_store_i4", 4)] {
            let store = |b: KernelBackend, dst: &mut [u8]| {
                let stats = if bits <= 4 {
                    kernel::fq_store_i4_on(b, &src, dst, -3.0, 3.0, bits)
                } else {
                    kernel::fq_store_i8_on(b, &src, dst, -3.0, 3.0, bits)
                };
                std::hint::black_box(stats);
                std::hint::black_box(dst.first());
            };
            let mut dst = vec![0u8; kernel::payload_bytes(n, bits)];
            let scalar =
                time_it("scalar", 2, iters, || store(KernelBackend::Scalar, &mut dst));
            for b in KernelBackend::ALL {
                let mut dst2 = vec![0u8; kernel::payload_bytes(n, bits)];
                let fused = time_it(b.key(), 2, iters, || store(b, &mut dst2));
                let speedup = scalar.mean_s / fused.mean_s;
                table.row(&[
                    n.to_string(),
                    kname.to_string(),
                    b.key().to_string(),
                    format!("{:.3}", scalar.mean_ms()),
                    format!("{:.3}", fused.mean_ms()),
                    format!("{speedup:.2}x"),
                ]);
                let rec = Value::object(vec![
                    ("bench", Value::from("fig3_online_stats")),
                    ("kernel", Value::from(kname)),
                    ("payload", Value::Bool(true)),
                    ("backend", Value::from(b.key())),
                    ("elems", Value::from(n)),
                    ("bits", Value::from(bits as usize)),
                    ("iters", Value::from(iters)),
                    ("scalar_ms", Value::from(scalar.mean_ms())),
                    ("fused_ms", Value::from(fused.mean_ms())),
                    ("speedup", Value::from(speedup)),
                ]);
                match append_bench_record(rec) {
                    Ok(path) => println!(
                        "recorded {} elems ({kname}) [{}] -> {}",
                        n,
                        b.key(),
                        path.display()
                    ),
                    Err(e) => eprintln!("could not record bench json: {e}"),
                }
            }
        }
    }
    table.print();
}

/// Per-site autotuning evidence: run the calibration-time backend
/// shootout on representative site shapes and record the measured
/// winner with `autotune: true` — proving which backend won per shape,
/// exactly the record the trainer caches per quantizer site.
fn autotune_section() {
    let mut table = Table::new(
        "Per-site kernel autotuning — measured winner per tensor shape",
        &["elems", "bits", "winner", "winner ms", "scalar ms", "speedup"],
    );
    let shapes: &[(usize, u32)] = if quick() {
        &[(65_536, 8), (262_144, 4)]
    } else {
        &[(65_536, 8), (1_048_576, 8), (1_048_576, 4), (4_194_304, 8)]
    };
    for &(elems, bits) in shapes {
        let at = kernel::autotune_minmax_fq(elems, bits);
        table.row(&[
            elems.to_string(),
            bits.to_string(),
            at.backend.key().to_string(),
            format!("{:.3}", at.best_s * 1e3),
            format!("{:.3}", at.scalar_s * 1e3),
            format!("{:.2}x", at.speedup()),
        ]);
        let rec = Value::object(vec![
            ("bench", Value::from("fig3_online_stats")),
            ("kernel", Value::from("minmax_fq")),
            ("autotune", Value::Bool(true)),
            ("backend", Value::from(at.backend.key())),
            ("elems", Value::from(at.elems)),
            ("bits", Value::from(at.bits as usize)),
            ("scalar_ms", Value::from(at.scalar_s * 1e3)),
            ("fused_ms", Value::from(at.best_s * 1e3)),
            ("speedup", Value::from(at.speedup())),
        ]);
        match append_bench_record(rec) {
            Ok(path) => println!(
                "recorded autotune {} elems @ {bits}b -> {} [{}]",
                elems,
                path.display(),
                at.backend.key()
            ),
            Err(e) => eprintln!("could not record bench json: {e}"),
        }
    }
    table.print();
}

fn contract_section() {
    if !Manifest::default_dir().join("manifest.json").exists() {
        println!("\nartifacts not built; skipping the runtime-contract section");
        return;
    }
    let engine = Engine::new().expect("engine");
    let mut cfg = TrainConfig::new("cnn").fully_quantized(Estimator::HINDSIGHT);
    cfg.steps = 30;
    cfg.n_train = 512;
    cfg.calib_batches = 2;
    let mut t = Trainer::new(&engine, cfg).unwrap();
    t.calibrate().unwrap();

    // (a) statistics sanity: ranges trail stats by one step (EMA)
    let mut range_updates = 0;
    for _ in 0..30 {
        t.train_step().unwrap();
        for i in 0..t.ranges.n_sites() {
            let s = t.ranges.last_stats(i);
            assert!(s[0] <= s[1], "stats must be ordered");
            assert!(s[0].is_finite() && s[1].is_finite());
        }
        range_updates += t.ranges.n_sites();
    }

    // (b) cost split: graph execution vs coordinator update
    let es = engine.stats();
    let graph_ms = es.execute_seconds / es.executions as f64 * 1e3;
    let q = t.ranges.n_sites();
    // measure the O(Q) EMA update in isolation
    let mut ranges: Vec<[f32; 2]> = vec![[-1.0, 1.0]; q];
    let stats: Vec<[f32; 2]> = vec![[-2.0, 2.0]; q];
    let t0 = Instant::now();
    let iters = 100_000;
    for _ in 0..iters {
        for i in 0..q {
            ranges[i] = hindsight::quant::ema_update(ranges[i], stats[i], 0.9);
        }
    }
    let update_us = t0.elapsed().as_secs_f64() / iters as f64 * 1e6;

    let mut table = Table::new(
        "Fig. 3 — online statistics contract (cnn, in-hindsight)",
        &["Quantity", "Value"],
    );
    table.row(&["quantizer sites Q".into(), q.to_string()]);
    table.row(&["range-state updates over run".into(), range_updates.to_string()]);
    table.row(&["graph execution / step".into(), format!("{graph_ms:.1} ms")]);
    table.row(&[
        "coordinator EMA update / step".into(),
        format!("{update_us:.3} µs"),
    ]);
    table.row(&[
        "coordinator share".into(),
        format!("{:.5}%", update_us / 10.0 / graph_ms),
    ]);
    table.print();
    println!(
        "the eqs. 2-3 update is ~{:.0}x cheaper than the step itself — the \
         'minimal hardware support' of paper Sec. 4 in numbers.",
        graph_ms * 1e3 / update_us
    );
    assert!(update_us < graph_ms * 1e3 / 100.0);
}

fn main() {
    hindsight::util::logging::init();
    kernel_section();
    payload_section();
    axis_kernel_section();
    dispatch_section();
    autotune_section();
    contract_section();
}

//! Paper Fig. 3 (in-hindsight hardware framework), realized as the
//! runtime contract: *static* ranges go into the executable, *online*
//! accumulator statistics come back out of the same execution, and the
//! between-step update is a handful of flops in the coordinator.
//!
//! Measures: (a) that the stats outputs equal the true tensor extrema
//! (cross-checked against the eval of the same tensors), (b) the
//! coordinator-side update cost per step vs the graph execution cost —
//! the "minimal hardware support" claim in numbers.
//!
//!   cargo bench --bench fig3_online_stats

use std::time::Instant;

use hindsight::coordinator::{Estimator, TrainConfig, Trainer};
use hindsight::runtime::Engine;
use hindsight::util::bench::Table;

fn main() {
    hindsight::util::logging::init();
    let engine = Engine::new().expect("engine");
    let mut cfg = TrainConfig::new("cnn").fully_quantized(Estimator::Hindsight);
    cfg.steps = 30;
    cfg.n_train = 512;
    cfg.calib_batches = 2;
    let mut t = Trainer::new(&engine, cfg).unwrap();
    t.calibrate().unwrap();

    // (a) statistics sanity: ranges trail stats by one step (EMA)
    let mut range_updates = 0;
    for _ in 0..30 {
        t.train_step().unwrap();
        for i in 0..t.ranges.n_sites() {
            let s = t.ranges.last_stats(i);
            assert!(s[0] <= s[1], "stats must be ordered");
            assert!(s[0].is_finite() && s[1].is_finite());
        }
        range_updates += t.ranges.n_sites();
    }

    // (b) cost split: graph execution vs coordinator update
    let es = engine.stats();
    let graph_ms = es.execute_seconds / es.executions as f64 * 1e3;
    let q = t.ranges.n_sites();
    // measure the O(Q) EMA update in isolation
    let mut ranges: Vec<[f32; 2]> = vec![[-1.0, 1.0]; q];
    let stats: Vec<[f32; 2]> = vec![[-2.0, 2.0]; q];
    let t0 = Instant::now();
    let iters = 100_000;
    for _ in 0..iters {
        for i in 0..q {
            ranges[i] = hindsight::quant::ema_update(ranges[i], stats[i], 0.9);
        }
    }
    let update_us = t0.elapsed().as_secs_f64() / iters as f64 * 1e6;

    let mut table = Table::new(
        "Fig. 3 — online statistics contract (cnn, in-hindsight)",
        &["Quantity", "Value"],
    );
    table.row(&["quantizer sites Q".into(), q.to_string()]);
    table.row(&["range-state updates over run".into(), range_updates.to_string()]);
    table.row(&["graph execution / step".into(), format!("{graph_ms:.1} ms")]);
    table.row(&[
        "coordinator EMA update / step".into(),
        format!("{update_us:.3} µs"),
    ]);
    table.row(&[
        "coordinator share".into(),
        format!("{:.5}%", update_us / 10.0 / graph_ms),
    ]);
    table.print();
    println!(
        "the eqs. 2-3 update is ~{:.0}x cheaper than the step itself — the \
         'minimal hardware support' of paper Sec. 4 in numbers.",
        graph_ms * 1e3 / update_us
    );
    assert!(update_us < graph_ms * 1e3 / 100.0);
}

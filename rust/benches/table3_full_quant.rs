//! Paper Table 3: fully quantized training (W8/A8/G8) across the three
//! architecture families on the Tiny ImageNet stand-in.  Every row is a
//! typed `QuantScheme` built through `QuantScheme::fully_quantized`
//! (the in-hindsight row is exactly `w:current:8 a:hindsight:8
//! g:hindsight:8`, i.e. `QuantScheme::w8a8g8()`); the row set runs as
//! one estimator×seed grid through `GridSpec` + the grid executor (see
//! `common::estimator_table`), not a hand-rolled loop.
//!
//!   cargo bench --bench table3_full_quant

mod common;

use common::{estimator_table, Mode};

fn main() {
    hindsight::util::logging::init();
    // paper Table 3 columns, one per architecture
    let paper_resnet = [
        ("FP32", "58.97 ± 0.13"),
        ("Current min-max", "58.77 ± 0.73"),
        ("Running min-max", "59.20 ± 0.25"),
        ("DSGC", "59.07 ± 0.33"),
        ("In-hindsight min-max", "58.99 ± 0.44"),
    ];
    let paper_vgg = [
        ("FP32", "53.79 ± 0.30"),
        ("Current min-max", "53.28 ± 0.43"),
        ("Running min-max", "53.36 ± 0.27"),
        ("DSGC", "52.84 ± 0.28"),
        ("In-hindsight min-max", "53.25 ± 0.41"),
    ];
    let paper_mbv2 = [
        ("FP32", "59.61 ± 0.37"),
        ("Current min-max", "58.88 ± 0.73"),
        ("Running min-max", "59.69 ± 0.09"),
        ("DSGC", "59.10 ± 0.44"),
        ("In-hindsight min-max", "59.28 ± 0.20"),
    ];
    for (model, paper) in [
        ("resnet_tiny", &paper_resnet),
        ("vgg_tiny", &paper_vgg),
        ("mobilenet_tiny", &paper_mbv2),
    ] {
        let table = estimator_table(
            &format!("Table 3 — fully quantized W8/A8/G8 ({model} / SynthTiny)"),
            model,
            Mode::Full,
            paper,
        );
        table.print();
        common::assert_rows_close_to_fp32(&table, 25.0);
    }
    println!(
        "shape check: paper finds in-hindsight on par with dynamic methods on \
         all three architectures (within ~0.5% of FP32), with only running \
         min-max slightly ahead on MobileNetV2."
    );
}

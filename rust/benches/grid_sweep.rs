//! Grid-engine smoke: expand a tiny 4-cell scheme grid, run it on the
//! 2-worker executor into the resumable run store, then re-run and
//! require that *zero* cells execute (all served from the store), and
//! that the parallel ordering is bit-identical to the serial path.
//!
//! With compiled artifacts present the cells run real trainers; without
//! them (CI's bench-smoke job) a deterministic synthetic trainer stands
//! in — the expansion, executor, store and resume logic under test are
//! identical either way.  The store lands in `HINDSIGHT_GRID_STORE`
//! (default `grid_smoke_store/`), one `cell-*.json` per cell, so CI can
//! assert all 4 cells persisted.
//!
//!   cargo bench --bench grid_sweep

use hindsight::coordinator::executor::{run_grid_with, summarize};
use hindsight::coordinator::{
    grid_rows, run_grid, CellOutcome, CellRun, GridCell, GridOptions, GridSpec, RunStore,
    TrainConfig,
};
use hindsight::metrics::RunRecord;
use hindsight::runtime::manifest::Manifest;
use hindsight::util::bench::{append_bench_record, quick};
use hindsight::util::json::Value;

const TEMPLATE: &str = "g:{hindsight,current,running,tqt}:8";

fn run_cells(cells: &[GridCell], opts: &GridOptions, real: bool) -> Vec<CellRun> {
    if real {
        run_grid(cells, opts)
    } else {
        // deterministic synthetic trainer: the record depends only on
        // the cell's label, like a real run on its configuration.  It
        // still runs one fused-kernel pass through the *dispatched*
        // entry point inside the worker thread, so the `backend` field
        // recorded below reflects in-worker dispatch even without
        // artifacts (kernel results are backend-invariant, so the
        // record stays bit-identical across backends).
        run_grid_with(cells, opts, |_| Ok(()), |_: &mut (), cell: &GridCell| {
            let mut probe: Vec<f32> = (0..4096).map(|i| (i as f32 * 0.37).sin()).collect();
            let (lo, hi) = hindsight::quant::kernel::minmax_fq(&mut probe, -1.0, 1.0, 8);
            anyhow::ensure!(lo < hi, "kernel probe produced a degenerate hull");
            Ok(RunRecord::synthetic(&cell.label, 6))
        })
    }
}

fn main() {
    hindsight::util::logging::init();
    let real = Manifest::default_dir().join("manifest.json").exists();
    let store_dir = std::env::var("HINDSIGHT_GRID_STORE")
        .unwrap_or_else(|_| "grid_smoke_store".to_string());
    // fresh store: this smoke proves the ran→cached transition
    let _ = std::fs::remove_dir_all(&store_dir);

    let mut base = TrainConfig::new("mlp");
    if real {
        base.steps = if quick() { 6 } else { 24 };
        base.n_train = 128;
        base.n_val = 64;
        base.calib_batches = 1;
    }
    let grid = GridSpec::new(TEMPLATE, &[1]).expect("grid template");
    let cells = grid.expand(&base);
    assert_eq!(cells.len(), 4, "the smoke grid is 4 cells");

    // pass 1: everything runs, 2 workers, write-through to the store
    let opts = GridOptions {
        workers: 2,
        store: Some(RunStore::open(&store_dir).expect("run store")),
        use_cache: true,
        fail_fast: false,
    };
    let first = run_cells(&cells, &opts, real);
    let s1 = summarize(&first);
    println!(
        "pass 1 ({}): {} ran, {} cached, {} failed",
        if real { "engine" } else { "synthetic" },
        s1.ran,
        s1.cached,
        s1.failed
    );
    assert_eq!(s1.ran, 4, "first pass must execute every cell");
    assert_eq!(s1.failed, 0);
    assert_eq!(opts.store.as_ref().unwrap().len(), 4, "4 cells persisted");

    // pass 2 (resume): zero executions, all four served from the store
    let second = run_cells(&cells, &opts, real);
    let s2 = summarize(&second);
    println!("pass 2 (resume): {} ran, {} cached, {} failed", s2.ran, s2.cached, s2.failed);
    assert_eq!(s2.ran, 0, "resume must execute zero trainer runs");
    assert_eq!(s2.cached, 4);
    for (a, b) in first.iter().zip(&second) {
        assert_eq!(a.index, b.index, "grid ordering is deterministic");
        assert_eq!(
            a.outcome.record(),
            b.outcome.record(),
            "cached record differs for '{}'",
            a.label
        );
    }

    // serial parity: a 1-worker uncached run is bit-identical in
    // ordering and aggregates to the 2-worker pass
    let serial_opts = GridOptions {
        workers: 1,
        store: None,
        use_cache: false,
        fail_fast: false,
    };
    let serial = run_cells(&cells, &serial_opts, real);
    let rows_par = grid_rows(&first);
    let rows_ser = grid_rows(&serial);
    assert_eq!(rows_par.len(), rows_ser.len());
    for (p, s) in rows_par.iter().zip(&rows_ser) {
        assert_eq!(p.label, s.label);
        let bits = |xs: &[f64]| xs.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&p.agg.accs), bits(&s.agg.accs), "row '{}'", p.label);
    }
    println!("parallel(2) == serial(1): aggregates bit-identical across {} rows", rows_par.len());

    let cached_labels: Vec<Value> = second
        .iter()
        .filter(|r| matches!(r.outcome, CellOutcome::Cached(_)))
        .map(|r| Value::from(r.label.clone()))
        .collect();
    // the cells' kernel work (real trainers and the simulator alike)
    // routes through the dispatched quant::kernel entry points: record
    // which backend this sweep actually ran on, so the perf trajectory
    // can attribute end-to-end numbers to a backend
    let record = Value::object(vec![
        ("bench", Value::from("grid_sweep")),
        ("template", Value::from(TEMPLATE)),
        ("backend", Value::from(hindsight::quant::kernel::backend().key())),
        ("cells", Value::from(cells.len())),
        ("workers", Value::from(2usize)),
        ("resumed_cached", Value::from(cached_labels.len())),
        ("engine", Value::from(real)),
        ("store", Value::from(store_dir.clone())),
        ("labels", Value::Array(cached_labels)),
    ]);
    match append_bench_record(record) {
        Ok(path) => println!("recorded grid smoke to {}", path.display()),
        Err(e) => eprintln!("warning: could not append bench record: {e}"),
    }
}

//! Sec. 3.2 latency claim: dynamic quantization slows the step down
//! (the paper cites a ~20% PyTorch-CPU MLP study).  Measures end-to-end
//! train-step wall clock per estimator on this testbed: the dynamic modes
//! pay an extra full-tensor reduction *before* quantization inside the
//! same graph, the static mode does not.
//!
//!   cargo bench --bench perf_step_latency

mod common;

use hindsight::coordinator::{Estimator, Trainer};
use hindsight::runtime::Engine;
use hindsight::util::bench::{env_usize, quick, Table};

fn main() {
    hindsight::util::logging::init();
    let engine = Engine::new().expect("engine");
    let iters = if quick() { 5 } else { env_usize("HINDSIGHT_PERF_ITERS", 30) } as u64;

    let mut table = Table::new(
        "Step latency by estimator (cnn + resnet_tiny, fully quantized)",
        &["Model", "Method", "Static", "ms/step", "vs hindsight"],
    );
    for model in ["cnn", "resnet_tiny"] {
        let mut hindsight_ms = f64::NAN;
        for est in [
            Estimator::HINDSIGHT,
            Estimator::CURRENT,
            Estimator::RUNNING,
            Estimator::FP32,
        ] {
            let s = common::scale();
            let mut cfg = common::base_cfg(model, &s).fully_quantized(est);
            cfg.steps = iters;
            cfg.calib_batches = 0;
            cfg.log_every = 0;
            let mut t = Trainer::new(&engine, cfg).unwrap();
            for _ in 0..3 {
                t.train_step().unwrap();
            }
            let t0 = std::time::Instant::now();
            for _ in 0..iters {
                t.train_step().unwrap();
            }
            let ms = t0.elapsed().as_secs_f64() / iters as f64 * 1e3;
            if est == Estimator::HINDSIGHT {
                hindsight_ms = ms;
            }
            table.row(&[
                model.into(),
                est.name().into(),
                common::static_cell(est),
                format!("{ms:.1}"),
                format!("{:+.1}%", (ms / hindsight_ms - 1.0) * 100.0),
            ]);
        }
    }
    table.print();
    println!(
        "note: on this CPU-PJRT testbed XLA fuses the dynamic modes' extra \
         reduction cheaply; the hardware-level traffic gap is the analytic \
         Table 5 / fig4 result (the simulated accelerator), while this \
         measures the end-to-end software overhead (paper cites ~20% for \
         PyTorch dynamic quantization)."
    );
}

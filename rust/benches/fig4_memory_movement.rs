//! Paper Fig. 4 (memory-movement schematic), realized in numbers: the
//! MAC-array machine executes the same GEMM under both quantization
//! policies and reports per-phase DMA bytes — the arrows of the figure.
//!
//!   cargo bench --bench fig4_memory_movement

use hindsight::quant::kernel;
use hindsight::quant::QuantParams;
use hindsight::simulator::backward::{self, BwdBits};
use hindsight::simulator::machine::{MacArray, Policy};
use hindsight::simulator::traffic;
use hindsight::simulator::LayerGeom;
use hindsight::util::bench::{append_bench_record, Table};
use hindsight::util::json::Value;
use hindsight::util::rng::Pcg32;

fn main() {
    let mac = MacArray::default();
    let (m, k, n) = (256, 512, 256);
    let mut rng = Pcg32::new(1, 1);
    let a: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
    let w: Vec<f32> = (0..k * n).map(|_| rng.normal() * 0.2).collect();
    let qp = QuantParams::from_range(-4.0, 4.0, 8);

    let st = mac.gemm(&a, &w, m, k, n, qp, qp, 8, Policy::Static { qmin: -60.0, qmax: 60.0 });
    let dy = mac.gemm(&a, &w, m, k, n, qp, qp, 8, Policy::Dynamic);

    let kb = |b: u64| format!("{:.1} KB", b as f64 / 1024.0);
    let mut t = Table::new(
        &format!("Fig. 4 — per-phase DMA bytes, {m}x{k} @ {k}x{n} int8 GEMM"),
        &["Phase", "Static", "Dynamic"],
    );
    t.row(&["load weights".into(), kb(st.phases.weight_load), kb(dy.phases.weight_load)]);
    t.row(&["load input".into(), kb(st.phases.input_load), kb(dy.phases.input_load)]);
    t.row(&["save 32-bit acc output".into(), kb(st.phases.acc_store), kb(dy.phases.acc_store)]);
    t.row(&["reload acc output".into(), kb(st.phases.acc_reload), kb(dy.phases.acc_reload)]);
    t.row(&["save quantized output".into(), kb(st.phases.output_store), kb(dy.phases.output_store)]);
    t.row(&["TOTAL".into(), kb(st.phases.total()), kb(dy.phases.total())]);
    t.print();

    println!(
        "dynamic/static ratio: {:.2}x; identical MAC work ({} cycles each); \
         both outputs quantized to the same 8-bit grid.",
        dy.phases.total() as f64 / st.phases.total() as f64,
        st.cycles
    );
    // the figure's invariants
    assert_eq!(st.phases.acc_store, 0);
    assert_eq!(st.phases.acc_reload, 0);
    assert!(dy.phases.acc_store > 0 && dy.phases.acc_reload > 0);
    assert_eq!(st.cycles, dy.cycles);
    // static quantization with a generous precomputed range stays close to
    // the dynamically quantized output (the in-hindsight premise)
    let cos = hindsight::quant::cosine_similarity(&st.output, &dy.output);
    println!("cosine(static output, dynamic output) = {cos:.5}");
    assert!(cos > 0.995);

    // backward leg (paper: "the backwards pass follows analogously"):
    // quantize-and-store G_X through the fused single-pass kernel and tie
    // the bytes moved back to the closed-form bwd accounting
    let geom = traffic::table5_layers()[0];
    let bits = BwdBits::default();
    let gx_elems = geom.input_elems() as usize;
    let mut gx: Vec<f32> = (0..gx_elems).map(|_| rng.normal() * 0.01).collect();
    let (stats, bits_moved) = backward::store_gx_static(&mut gx, -0.04, 0.04, bits);
    println!(
        "backward G_X store ({}, fused single pass): stats [{:+.4}, {:+.4}], \
         {:.0} KB moved == the closed-form G_X store term",
        geom.name(),
        stats.0,
        stats.1,
        bits_moved as f64 / 8.0 / 1024.0,
    );
    assert_eq!(bits_moved, geom.input_elems() * bits.b_g);

    // tentpole invariant: static-store traffic is the *measured* size of
    // the integer payload buffer the store emitted, not f32 accounting.
    // The forward static output store billed exactly one code byte per
    // output element...
    assert_eq!(
        st.phases.output_store,
        kernel::payload_bytes(m * n, 8) as u64,
        "static output store must bill the integer payload buffer"
    );
    // ...and a 4-bit backward store bills the nibble-packed buffer: two
    // codes per byte, half the bytes of the 8-bit store above.
    let mut gx4: Vec<f32> = (0..gx_elems).map(|_| rng.normal() * 0.01).collect();
    let (_, moved4) =
        backward::store_gx_static(&mut gx4, -0.04, 0.04, BwdBits { b_g: 4, ..bits });
    assert_eq!(moved4, kernel::payload_bytes(gx_elems, 4) as u64 * 8);
    println!(
        "4-bit G_X store packs two codes per byte: {gx_elems} elems -> {} payload bytes \
         ({:.0} KB, half the 8-bit store)",
        moved4 / 8,
        moved4 as f64 / 8.0 / 1024.0,
    );

    // transformer leg: an attention block's input-gradient store goes
    // through the same fused kernel — bill the nibble-packed payload and
    // drop a transformer-labelled record into the bench trajectory
    // (no kernel/speedup pair, so the bench-report gate skips it)
    let attn = LayerGeom::attention("attn (mhsa)", 197, 384, 6, 64);
    let n = attn.input_elems() as usize;
    let mut agx: Vec<f32> = (0..n).map(|_| rng.normal() * 0.01).collect();
    let (astats, amoved) =
        backward::store_gx_static(&mut agx, -0.04, 0.04, BwdBits { b_g: 4, ..bits });
    assert_eq!(amoved, kernel::payload_bytes(n, 4) as u64 * 8);
    println!(
        "transformer G_X store ({}, 4-bit): stats [{:+.4}, {:+.4}], {:.0} KB moved",
        attn.name(),
        astats.0,
        astats.1,
        amoved as f64 / 8.0 / 1024.0,
    );
    let path = append_bench_record(Value::object(vec![
        ("bench", "fig4_memory_movement".into()),
        ("workload", "vit_s16".into()),
        ("layer_kind", "attention".into()),
        ("layer", attn.name().into()),
        ("gx_elems", n.into()),
        ("payload_kb", (amoved as f64 / 8.0 / 1024.0).into()),
    ]))
    .expect("bench record");
    println!("transformer record appended to {}", path.display());
}

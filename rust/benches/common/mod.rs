//! Shared bench scaffolding: estimator-sweep tables in the paper's layout
//! with the paper's reference rows printed alongside.
//!
//! Scale knobs (defaults sized for a CPU testbed; raise for longer runs):
//!   HINDSIGHT_BENCH_STEPS   training steps per run      (default 120)
//!   HINDSIGHT_BENCH_SEEDS   seeds per row               (default 2)
//!   HINDSIGHT_BENCH_QUICK=1 tiny CI-scale run (24 steps, 1 seed)

use hindsight::coordinator::{
    grid_rows, run_cells_on, Estimator, GridOptions, GridSpec, QuantScheme, TrainConfig,
};
use hindsight::runtime::Engine;
use hindsight::util::bench::{env_usize, quick, Table};

pub struct Scale {
    pub steps: u64,
    pub seeds: Vec<u64>,
    pub n_train: usize,
    pub n_val: usize,
}

pub fn scale() -> Scale {
    if quick() {
        Scale {
            steps: 24,
            seeds: vec![1],
            n_train: 256,
            n_val: 128,
        }
    } else {
        let steps = env_usize("HINDSIGHT_BENCH_STEPS", 120) as u64;
        let n_seeds = env_usize("HINDSIGHT_BENCH_SEEDS", 2);
        Scale {
            steps,
            seeds: (1..=n_seeds as u64).collect(),
            n_train: 2048,
            n_val: 512,
        }
    }
}

pub fn base_cfg(model: &str, s: &Scale) -> TrainConfig {
    let mut c = TrainConfig::new(model);
    c.steps = s.steps;
    c.n_train = s.n_train;
    c.n_val = s.n_val;
    c.lr = 0.05;
    c
}

/// Mode of an estimator-comparison table.
#[derive(Clone, Copy, PartialEq)]
pub enum Mode {
    GradOnly,
    ActOnly,
    Full,
}

/// Run the paper's estimator-comparison protocol for one model and print
/// the table with the paper's reference column.
///
/// `paper` — (estimator, paper cell) reference values for the caption.
pub fn estimator_table(
    title: &str,
    model: &str,
    mode: Mode,
    paper: &[(&str, &str)],
) -> Table {
    let engine = Engine::new().expect("engine (run `make artifacts`?)");
    let s = scale();
    let mut table = Table::new(
        title,
        &["Method", "Static", "Val. Acc. (%)", "paper (TinyImageNet)", "ms/step"],
    );
    // the whole registry: the paper's five rows plus the literature
    // estimators ride along with "-" in the paper column.  Each row is
    // a typed QuantScheme; the row set is a one-alternation GridSpec so
    // the table shares the grid engine's expansion/order/label path.
    // search estimators apply to gradients only
    let ests: Vec<Estimator> = Estimator::all()
        .filter(|est| !(est.needs_search() && mode == Mode::ActOnly))
        .collect();
    let schemes: Vec<QuantScheme> = ests
        .iter()
        .map(|&est| match mode {
            Mode::GradOnly => QuantScheme::grad_only(est),
            Mode::ActOnly => QuantScheme::act_only(est),
            // fully_quantized applies the paper-Table-3 act fallback for
            // search estimators
            Mode::Full => QuantScheme::fully_quantized(est),
        })
        .collect();
    let grid = GridSpec::alternation(&schemes, &s.seeds).expect("estimator grid");
    assert_eq!(
        grid.schemes().len(),
        ests.len(),
        "mode schemes must stay distinct per estimator"
    );
    let cells = grid.expand(&base_cfg(model, &s));
    let rows = grid_rows(&run_cells_on(&engine, &cells, &GridOptions::serial()));
    for (est, row) in ests.iter().zip(&rows) {
        assert!(
            !row.runs.is_empty(),
            "{}: every cell of row '{}' failed",
            est.name(),
            row.label
        );
        let paper_cell = paper
            .iter()
            .find(|(n, _)| *n == est.name())
            .map(|(_, c)| c.to_string())
            .unwrap_or_else(|| "-".into());
        table.row(&[
            est.name().to_string(),
            static_cell(*est),
            row.cell(),
            paper_cell,
            format!("{:.0}", row.sec_per_step * 1e3),
        ]);
    }
    table
}

pub fn static_cell(est: Estimator) -> String {
    if !est.enabled() {
        "n.a.".into()
    } else if est.is_static() {
        "yes".into()
    } else {
        "no".into()
    }
}

/// Shape check shared by the accuracy tables: every quantized row must be
/// within `tol` points of FP32 (the paper's "within 0.5%" claim, wider
/// here because runs are short and the dataset synthetic).
pub fn assert_rows_close_to_fp32(table: &Table, tol: f64) {
    if quick() {
        return; // QUICK is a smoke run — too short for accuracy shape
    }
    let acc = |row: &Vec<String>| -> f64 {
        row[2]
            .split('±')
            .next()
            .unwrap()
            .trim()
            .parse()
            .unwrap_or(f64::NAN)
    };
    let fp32 = table
        .rows()
        .iter()
        .find(|r| r[0] == "FP32")
        .map(acc)
        .expect("fp32 row");
    for row in table.rows() {
        let a = acc(row);
        assert!(
            (a - fp32).abs() <= tol,
            "{} acc {a:.2} deviates from FP32 {fp32:.2} by more than {tol}",
            row[0]
        );
    }
}

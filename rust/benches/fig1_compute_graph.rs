//! Paper Fig. 1 (quantized-training compute graph), realized: print the
//! quantizer wiring of a compiled model — which tensors pass through
//! Q_W / Q_Y / Q_G — and verify the structural invariants the figure
//! encodes (a gradient quantizer on every layer input except the first,
//! an activation quantizer on every feature map written to memory).
//!
//!   cargo bench --bench fig1_compute_graph

use hindsight::runtime::manifest::SiteKind;
use hindsight::runtime::Engine;
use hindsight::util::bench::Table;

fn main() {
    hindsight::util::logging::init();
    let engine = Engine::new().expect("engine");
    for model in ["cnn", "resnet_tiny", "vgg_tiny", "mobilenet_tiny"] {
        let spec = engine.manifest.model(model).unwrap();
        let mut t = Table::new(
            &format!("Fig. 1 wiring — {model} quantizers"),
            &["#", "Site", "Kind", "Feature shape"],
        );
        for s in &spec.sites {
            t.row(&[
                s.index.to_string(),
                s.name.clone(),
                match s.kind {
                    SiteKind::Act => "Q_Y (act)".into(),
                    SiteKind::Grad => "Q_G (grad)".into(),
                },
                format!("{:?}", s.feature_shape),
            ]);
        }
        t.print();
        let n_act = spec.act_sites().len();
        let n_grad = spec.grad_sites().len();
        println!(
            "  {model}: {} act quantizers, {} grad quantizers, {} params\n",
            n_act, n_grad, spec.n_params
        );
        // structural invariants of Fig. 1
        assert!(n_act > 0 && n_grad > 0);
        // the train graph threads ranges in and stats out — Fig. 3's contract
        let g = spec.graph("train").unwrap();
        assert!(g.input_index("ranges").is_ok());
        assert!(g.output_index("stats").is_ok());
        assert!(g.output_index("new_ranges").is_ok());
        let q = spec.n_sites();
        let ri = g.input_index("ranges").unwrap();
        assert_eq!(g.inputs[ri].shape, vec![q, 2]);
    }
    println!("fig1 wiring invariants hold for all models.");
}

//! Per-step coordinator cost of each estimator, including DSGC's periodic
//! golden-section search — the paper's "the update step can be very
//! expensive, as it requires estimating the objective function at
//! multiple clipping thresholds" in measured numbers.
//!
//!   cargo bench --bench perf_estimator_overhead

mod common;

use hindsight::coordinator::{Estimator, Trainer};
use hindsight::quant::dsgc;
use hindsight::runtime::Engine;
use hindsight::util::bench::{quick, time_it, Table};
use hindsight::util::rng::Pcg32;

fn main() {
    hindsight::util::logging::init();
    let engine = Engine::new().expect("engine");

    // 1) DSGC search cost in isolation, per tensor size
    let mut t1 = Table::new(
        "DSGC golden-section search cost (20 refinement iters)",
        &["Tensor elems", "ms/search", "objective evals"],
    );
    for n in [4_096usize, 65_536, 1_048_576] {
        let mut rng = Pcg32::new(n as u64, 1);
        let g: Vec<f32> = (0..n).map(|_| rng.normal() * 0.01).collect();
        let iters = if quick() { 3 } else { 10 };
        let timing = time_it("dsgc", 1, iters, || {
            let _ = dsgc::search_range(&g, 8, 20);
        });
        let r = dsgc::search_range(&g, 8, 20);
        t1.row(&[
            n.to_string(),
            format!("{:.2}", timing.mean_ms()),
            r.evals.to_string(),
        ]);
    }
    t1.print();

    // 2) end-to-end: steps/second with DSGC updates amortized vs hindsight
    let mut t2 = Table::new(
        "End-to-end estimator overhead (cnn, 40 steps, dsgc period 10)",
        &["Method", "total s", "ms/step", "dsgc objective evals"],
    );
    for est in [Estimator::Hindsight, Estimator::Dsgc] {
        let s = common::scale();
        let mut cfg = common::base_cfg("cnn", &s).grad_only(est);
        cfg.steps = if quick() { 10 } else { 40 };
        cfg.dsgc_period = 10;
        cfg.dsgc_iters = 20;
        cfg.calib_batches = 0;
        let steps = cfg.steps;
        let mut tr = Trainer::new(&engine, cfg).unwrap();
        let t0 = std::time::Instant::now();
        for _ in 0..steps {
            tr.train_step().unwrap();
        }
        let dt = t0.elapsed().as_secs_f64();
        t2.row(&[
            est.name().into(),
            format!("{dt:.2}"),
            format!("{:.1}", dt / steps as f64 * 1e3),
            tr.dsgc_evals.to_string(),
        ]);
    }
    t2.print();
    println!(
        "in-hindsight replaces every DSGC search (a full dump-graph run + \
         O(evals) fake-quant+cosine passes per site) with an O(Q) EMA — \
         that asymmetry is the paper's core efficiency argument."
    );
}

//! Per-step coordinator cost of each estimator, including the periodic
//! search passes — the paper's "the update step can be very expensive,
//! as it requires estimating the objective function at multiple clipping
//! thresholds" in measured numbers.
//!
//! Four sections:
//!  1. DSGC objective cost, fused (`kernel::fq_cosine`, no allocation)
//!     vs the scalar alloc-per-probe baseline it replaced — timed once
//!     per kernel backend (records carry a `backend` field) and
//!     appended to `BENCH_kernels.json`; runs without artifacts.
//!  2. search-pass cost per estimator family: DSGC's golden-section
//!     (iters + 3 full passes) vs sampled min-max (one strided
//!     subsample pass).
//!  3. per-tensor vs per-channel search cost (the `@pc` granularity
//!     axis, via the channel-replicating adapter) — appended to
//!     `BENCH_kernels.json`.
//!  4. end-to-end steps/second with searches amortized (needs built
//!     artifacts; skipped otherwise).
//!
//!   cargo bench --bench perf_estimator_overhead

mod common;

use hindsight::coordinator::{Estimator, Trainer};
use hindsight::estimator::{PerChannel, RangeEstimator, SampledMinMax};
use hindsight::quant::kernel::KernelBackend;
use hindsight::quant::{self, dsgc};
use hindsight::runtime::manifest::Manifest;
use hindsight::runtime::Engine;
use hindsight::util::bench::{append_bench_record, quick, time_it, Table};
use hindsight::util::json::Value;
use hindsight::util::rng::Pcg32;

fn grad_tensor(n: usize) -> Vec<f32> {
    let mut rng = Pcg32::new(n as u64, 1);
    (0..n).map(|_| rng.normal() * 0.01).collect()
}

/// The pre-kernel DSGC objective: allocate + two passes per probe.
fn scalar_objective(g: &[f32], qmin: f32, qmax: f32, bits: u32) -> f64 {
    let q = quant::fake_quant(g, qmin, qmax, bits);
    quant::cosine_similarity(g, &q) as f64
}

fn fused_vs_scalar_objective() {
    let mut table = Table::new(
        "DSGC search (20 refinement iters): fused objective per backend vs scalar alloc",
        &["Tensor elems", "backend", "scalar ms", "fused ms", "speedup", "evals"],
    );
    let iters = if quick() { 3 } else { 10 };
    for n in [4_096usize, 65_536, 1_048_576] {
        let g = grad_tensor(n);
        let scalar = time_it("scalar-search", 1, iters, || {
            // mirror the full pre-kernel search_range: the minmax pass
            // included, then alloc + two passes per probe
            let (gmin, gmax) = quant::minmax(&g);
            let (_, _, evals) = dsgc::golden_section_max(0.05, 1.0, 20, |alpha| {
                let a = alpha as f32;
                scalar_objective(&g, a * gmin, a * gmax, 8)
            });
            std::hint::black_box(evals);
        });
        // the eval count is a property of the search, not the backend
        let r = dsgc::search_range(&g, 8, 20);
        // time the *real* search (dsgc::search_range_on — one source of
        // truth with the trainer's path) with the objective pinned to
        // each backend.  (The parallel backend deliberately shares the
        // SIMD path here — the f64 reduction cannot fan out without
        // breaking bit-parity — so its row is a dispatch-overhead
        // check, not a speedup claim.)
        for b in KernelBackend::ALL {
            let fused = time_it(b.key(), 1, iters, || {
                std::hint::black_box(dsgc::search_range_on(b, &g, 8, 20));
            });
            let speedup = scalar.mean_s / fused.mean_s;
            table.row(&[
                n.to_string(),
                b.key().to_string(),
                format!("{:.2}", scalar.mean_ms()),
                format!("{:.2}", fused.mean_ms()),
                format!("{speedup:.2}x"),
                r.evals.to_string(),
            ]);
            let rec = Value::object(vec![
                ("bench", Value::from("perf_estimator_overhead")),
                ("kernel", Value::from("fq_cosine")),
                ("backend", Value::from(b.key())),
                ("elems", Value::from(n)),
                ("bits", Value::from(8usize)),
                ("iters", Value::from(iters)),
                ("scalar_ms", Value::from(scalar.mean_ms())),
                ("fused_ms", Value::from(fused.mean_ms())),
                ("speedup", Value::from(speedup)),
            ]);
            match append_bench_record(rec) {
                Ok(path) => println!("recorded {} elems [{}] -> {}", n, b.key(), path.display()),
                Err(e) => eprintln!("could not record bench json: {e}"),
            }
        }
    }
    table.print();
}

fn search_family_cost() {
    let mut table = Table::new(
        "Search-pass cost per estimator family (per site, per period)",
        &["Tensor elems", "DSGC ms", "sampled ms", "ratio"],
    );
    let iters = if quick() { 3 } else { 10 };
    for n in [65_536usize, 1_048_576] {
        let g = grad_tensor(n);
        let dsgc_t = time_it("dsgc", 1, iters, || {
            std::hint::black_box(dsgc::search_range(&g, 8, 20));
        });
        let mut sampled = SampledMinMax::default();
        let sampled_t = time_it("sampled", 1, iters, || {
            std::hint::black_box(sampled.search(&g, 8, 20));
        });
        table.row(&[
            n.to_string(),
            format!("{:.3}", dsgc_t.mean_ms()),
            format!("{:.4}", sampled_t.mean_ms()),
            format!("{:.0}x", dsgc_t.mean_s / sampled_t.mean_s),
        ]);
    }
    table.print();
    println!(
        "in-hindsight replaces the search entirely with an O(Q) EMA; among \
         searchers, a sampled pass is orders cheaper than DSGC's golden \
         section — the registry makes that a one-line config change."
    );
}

/// Per-tensor vs per-channel search cost: the per-channel adapter splits
/// the tensor into C strided slices and searches each independently, so
/// the total objective work is ~unchanged for DSGC (same element count)
/// plus one gather — the granularity tax is the gather, not the search.
fn granularity_cost() {
    let mut table = Table::new(
        "Search cost per granularity (64 channel groups)",
        &["Estimator", "Tensor elems", "per-tensor ms", "per-channel ms", "ratio"],
    );
    let iters = if quick() { 3 } else { 10 };
    let channels = 64usize;
    for n in [65_536usize, 1_048_576] {
        let g = grad_tensor(n);
        for (label, est) in [("DSGC", Estimator::DSGC), ("sampled", Estimator::SAMPLED_MINMAX)] {
            let dsgc_iters = 20;
            let mut pt = est.instantiate();
            let per_tensor = time_it("search-pt", 1, iters, || {
                std::hint::black_box(pt.search(&g, 8, dsgc_iters));
            });
            let mut pc = PerChannel::replicate(|| est.instantiate(), channels);
            let mut rows = vec![[0.0f32; 2]; channels];
            let per_channel = time_it("search-pc", 1, iters, || {
                std::hint::black_box(pc.search_rows(&g, 8, dsgc_iters, &mut rows));
            });
            let ratio = per_channel.mean_s / per_tensor.mean_s;
            table.row(&[
                label.to_string(),
                n.to_string(),
                format!("{:.3}", per_tensor.mean_ms()),
                format!("{:.3}", per_channel.mean_ms()),
                format!("{ratio:.2}x"),
            ]);
            let rec = Value::object(vec![
                ("bench", Value::from("perf_estimator_overhead")),
                ("kernel", Value::from("search_granularity")),
                ("estimator", Value::from(est.key())),
                ("elems", Value::from(n)),
                ("channels", Value::from(channels)),
                ("bits", Value::from(8usize)),
                ("iters", Value::from(iters)),
                ("per_tensor_ms", Value::from(per_tensor.mean_ms())),
                ("per_channel_ms", Value::from(per_channel.mean_ms())),
                ("ratio", Value::from(ratio)),
            ]);
            match append_bench_record(rec) {
                Ok(path) => {
                    println!("recorded {label} {n} elems (granularity) -> {}", path.display())
                }
                Err(e) => eprintln!("could not record bench json: {e}"),
            }
        }
    }
    table.print();
}

fn end_to_end() {
    if !Manifest::default_dir().join("manifest.json").exists() {
        println!("\nartifacts not built; skipping the end-to-end section");
        return;
    }
    let engine = Engine::new().expect("engine");
    let mut table = Table::new(
        "End-to-end estimator overhead (cnn, 40 steps, search period 10)",
        &["Method", "total s", "ms/step", "search evals"],
    );
    for est in [Estimator::HINDSIGHT, Estimator::DSGC, Estimator::SAMPLED_MINMAX] {
        let s = common::scale();
        let mut cfg = common::base_cfg("cnn", &s).grad_only(est);
        cfg.steps = if quick() { 10 } else { 40 };
        cfg.dsgc_period = 10;
        cfg.dsgc_iters = 20;
        cfg.calib_batches = 0;
        let steps = cfg.steps;
        let mut tr = Trainer::new(&engine, cfg).unwrap();
        let t0 = std::time::Instant::now();
        for _ in 0..steps {
            tr.train_step().unwrap();
        }
        let dt = t0.elapsed().as_secs_f64();
        table.row(&[
            est.name().into(),
            format!("{dt:.2}"),
            format!("{:.1}", dt / steps as f64 * 1e3),
            tr.search_evals.to_string(),
        ]);
    }
    table.print();
    println!(
        "in-hindsight replaces every search (a full dump-graph run + \
         O(evals) objective passes per site) with an O(Q) EMA — that \
         asymmetry is the paper's core efficiency argument."
    );
}

fn main() {
    hindsight::util::logging::init();
    fused_vs_scalar_objective();
    search_family_cost();
    granularity_cost();
    end_to_end();
}

//! Sweep-service smoke + throughput probe: bind `hindsight serve`'s
//! [`Server`] on an ephemeral port with the synthetic runner, measure
//! raw HTTP request overhead (`GET /healthz` round-trips), then drive a
//! 16-cell grid submission end-to-end over real TCP and record the
//! sweep wall time and the cache-hit behaviour of a resubmission.
//!
//! No artifacts needed: cells produce deterministic synthetic records,
//! so the bench exercises exactly the service plumbing (protocol, job
//! registry, cost queue, workers, store write-through) and none of the
//! training stack.
//!
//!   cargo bench --bench serve_http

use std::io::Write;
use std::net::{SocketAddr, TcpStream};
use std::time::{Duration, Instant};

use hindsight::service::protocol::read_response;
use hindsight::service::{CellRunner, ServeOptions, Server, ShardSpec};
use hindsight::util::bench::{append_bench_record, quick};
use hindsight::util::json::{self, Value};

const SUBMIT: &str =
    r#"{"grid":"g:{hindsight,current,tqt,banner}:{4,8}","model":"mlp","seeds":[1,2],"steps":8}"#;
const CELLS: usize = 16;

fn http(addr: SocketAddr, method: &str, path: &str, body: &str) -> (u16, Value) {
    let mut stream = TcpStream::connect(addr).expect("connect");
    stream
        .set_read_timeout(Some(Duration::from_secs(10)))
        .expect("read timeout");
    write!(
        stream,
        "{method} {path} HTTP/1.1\r\nHost: bench\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    )
    .expect("request write");
    let (status, bytes) = read_response(&mut stream).expect("response read");
    let text = String::from_utf8(bytes).expect("utf8 body");
    (status, json::parse(text.trim()).expect("json body"))
}

fn get_usize(doc: &Value, key: &str) -> usize {
    doc.get(key)
        .and_then(|v| v.as_usize())
        .unwrap_or_else(|| panic!("missing '{key}' in {doc}"))
}

fn main() {
    hindsight::util::logging::init();
    let store_dir = std::env::var("HINDSIGHT_SERVE_STORE")
        .unwrap_or_else(|_| "serve_bench_store".to_string());
    // fresh store: the first pass must execute every cell
    let _ = std::fs::remove_dir_all(&store_dir);

    let server = Server::bind(ServeOptions {
        addr: "127.0.0.1:0".into(),
        workers: 2,
        store_dir: store_dir.clone().into(),
        shard: ShardSpec::solo(),
        runner: CellRunner::Synthetic,
        poll_ms: 500,
    })
    .expect("bind");
    let addr = server.local_addr().expect("local addr");
    let handle = std::thread::spawn(move || server.run().expect("server run"));

    // raw protocol overhead: healthz round-trips on fresh connections
    let reqs = if quick() { 25 } else { 200 };
    let t0 = Instant::now();
    for _ in 0..reqs {
        let (status, _) = http(addr, "GET", "/healthz", "");
        assert_eq!(status, 200);
    }
    let us_per_req = t0.elapsed().as_micros() as f64 / reqs as f64;
    println!("healthz: {reqs} round-trips, {us_per_req:.0} us/request");

    // the sweep: submit, poll to completion, fetch results
    let t0 = Instant::now();
    let (status, doc) = http(addr, "POST", "/jobs", SUBMIT);
    assert_eq!(status, 202, "first submission is created: {doc}");
    let job = doc.get("job").and_then(|j| j.as_str()).expect("job id").to_string();
    assert_eq!(get_usize(&doc, "total"), CELLS);
    let deadline = Instant::now() + Duration::from_secs(60);
    let done = loop {
        let (status, doc) = http(addr, "GET", &format!("/jobs/{job}"), "");
        assert_eq!(status, 200);
        if doc.get("complete").and_then(|c| c.as_bool()) == Some(true) {
            break doc;
        }
        assert!(Instant::now() < deadline, "sweep did not complete: {doc}");
        std::thread::sleep(Duration::from_millis(20));
    };
    let sweep_ms = t0.elapsed().as_millis() as usize;
    assert_eq!(get_usize(&done, "executed"), CELLS, "fresh store: all cells execute");
    assert_eq!(get_usize(&done, "failed"), 0);
    let (status, results) = http(addr, "GET", &format!("/jobs/{job}/results"), "");
    assert_eq!(status, 200);
    let rows = results.get("rows").and_then(|r| r.as_array()).expect("rows").len();
    assert_eq!(rows, 8, "one aggregated row per scheme");
    println!("sweep: {CELLS} cells -> {rows} rows in {sweep_ms} ms");

    // resubmission: idempotent id, zero new executions
    let (status, doc) = http(addr, "POST", "/jobs", SUBMIT);
    assert_eq!(status, 200, "resubmission of a known job: {doc}");
    assert_eq!(doc.get("job").and_then(|j| j.as_str()), Some(job.as_str()));
    assert_eq!(get_usize(&doc, "executed"), CELLS, "resubmission executes nothing new");

    let (status, _) = http(addr, "POST", "/shutdown", "{}");
    assert_eq!(status, 200);
    handle.join().expect("server thread");

    let record = Value::object(vec![
        ("bench", Value::from("serve_http")),
        ("cells", Value::from(CELLS)),
        ("rows", Value::from(rows)),
        ("healthz_requests", Value::from(reqs)),
        ("healthz_us_per_request", Value::from(us_per_req)),
        ("sweep_ms", Value::from(sweep_ms)),
        ("workers", Value::from(2usize)),
        ("store", Value::from(store_dir)),
    ]);
    match append_bench_record(record) {
        Ok(path) => println!("recorded serve smoke to {}", path.display()),
        Err(e) => eprintln!("warning: could not append bench record: {e}"),
    }
}

//! Sweep-service smoke + throughput probe: bind `hindsight serve`'s
//! [`Server`] on an ephemeral port with the synthetic runner, measure
//! raw HTTP request overhead (`GET /healthz` round-trips), drive a
//! 16-cell grid submission end-to-end over real TCP, then measure the
//! parse-once/serve-many results path: one cold `GET /jobs/<id>/results`
//! (parses every cell document, assembles and caches the body) against
//! a stream of warm GETs (byte-identical `Arc`'d body, zero JSON work).
//! The cold/warm speedup lands in BENCH_kernels.json as a
//! `raw_doc_results` record, which CI gates with
//! `bench-report --kernel raw_doc_results --floor 2.0`.
//!
//! No artifacts needed: cells produce deterministic synthetic records,
//! so the bench exercises exactly the service plumbing (protocol, job
//! registry, cost queue, workers, store write-through) and none of the
//! training stack.
//!
//!   cargo bench --bench serve_http

use std::io::Write;
use std::net::{SocketAddr, TcpStream};
use std::time::{Duration, Instant};

use hindsight::service::protocol::read_response;
use hindsight::service::{CellRunner, ServeOptions, Server, ShardSpec};
use hindsight::util::bench::{append_bench_record, quick};
use hindsight::util::json::{self, Value};

// 400 steps per cell makes each stored record a few KB, so the cold
// path's per-document parse cost is well above HTTP round-trip noise
const SUBMIT: &str =
    r#"{"grid":"g:{hindsight,current,tqt,banner}:{4,8}","model":"mlp","seeds":[1,2],"steps":400}"#;
const CELLS: usize = 16;

fn http(addr: SocketAddr, method: &str, path: &str, body: &str) -> (u16, Value) {
    let (status, bytes) = http_bytes(addr, method, path, body);
    let text = String::from_utf8(bytes).expect("utf8 body");
    (status, json::parse(text.trim()).expect("json body"))
}

/// Like [`http`] but leaves the body unparsed — the warm-path timing
/// loop must measure the server, not this client's JSON parser.
fn http_bytes(addr: SocketAddr, method: &str, path: &str, body: &str) -> (u16, Vec<u8>) {
    let mut stream = TcpStream::connect(addr).expect("connect");
    stream
        .set_read_timeout(Some(Duration::from_secs(10)))
        .expect("read timeout");
    write!(
        stream,
        "{method} {path} HTTP/1.1\r\nHost: bench\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    )
    .expect("request write");
    read_response(&mut stream).expect("response read")
}

fn get_usize(doc: &Value, key: &str) -> usize {
    doc.get(key)
        .and_then(|v| v.as_usize())
        .unwrap_or_else(|| panic!("missing '{key}' in {doc}"))
}

fn main() {
    hindsight::util::logging::init();
    let store_dir = std::env::var("HINDSIGHT_SERVE_STORE")
        .unwrap_or_else(|_| "serve_bench_store".to_string());
    // fresh store: the first pass must execute every cell
    let _ = std::fs::remove_dir_all(&store_dir);

    let server = Server::bind(ServeOptions {
        addr: "127.0.0.1:0".into(),
        workers: 2,
        store_dir: store_dir.clone().into(),
        shard: ShardSpec::solo(),
        runner: CellRunner::Synthetic,
        poll_ms: 500,
        queue_cap: usize::MAX,
        synthetic_delay_ms: 0,
    })
    .expect("bind");
    let addr = server.local_addr().expect("local addr");
    let handle = std::thread::spawn(move || server.run().expect("server run"));

    // raw protocol overhead: healthz round-trips on fresh connections
    let reqs = if quick() { 25 } else { 200 };
    let t0 = Instant::now();
    for _ in 0..reqs {
        let (status, _) = http(addr, "GET", "/healthz", "");
        assert_eq!(status, 200);
    }
    let us_per_req = t0.elapsed().as_micros() as f64 / reqs as f64;
    println!("healthz: {reqs} round-trips, {us_per_req:.0} us/request");

    // the sweep: submit, poll to completion, fetch results
    let t0 = Instant::now();
    let (status, doc) = http(addr, "POST", "/jobs", SUBMIT);
    assert_eq!(status, 202, "first submission is created: {doc}");
    let job = doc.get("job").and_then(|j| j.as_str()).expect("job id").to_string();
    assert_eq!(get_usize(&doc, "total"), CELLS);
    let deadline = Instant::now() + Duration::from_secs(60);
    let done = loop {
        let (status, doc) = http(addr, "GET", &format!("/jobs/{job}"), "");
        assert_eq!(status, 200);
        if doc.get("complete").and_then(|c| c.as_bool()) == Some(true) {
            break doc;
        }
        assert!(Instant::now() < deadline, "sweep did not complete: {doc}");
        std::thread::sleep(Duration::from_millis(20));
    };
    let sweep_ms = t0.elapsed().as_millis() as usize;
    assert_eq!(get_usize(&done, "executed"), CELLS, "fresh store: all cells execute");
    assert_eq!(get_usize(&done, "failed"), 0);

    // cold results GET: the first ever — every cell document parses
    // (once, into the store's doc cache) and the body is assembled
    let results_path = format!("/jobs/{job}/results");
    let t0 = Instant::now();
    let (status, cold) = http_bytes(addr, "GET", &results_path, "");
    let cold_ms = t0.elapsed().as_secs_f64() * 1e3;
    assert_eq!(status, 200);
    let results = json::parse(std::str::from_utf8(&cold).expect("utf8").trim()).expect("results");
    let rows = results.get("rows").and_then(|r| r.as_array()).expect("rows").len();
    assert_eq!(rows, 8, "one aggregated row per scheme");
    println!("sweep: {CELLS} cells -> {rows} rows in {sweep_ms} ms");

    // warm results GETs: served from the per-job cache as shared bytes
    let warm_reqs = if quick() { 10 } else { 100 };
    let t0 = Instant::now();
    for _ in 0..warm_reqs {
        let (status, warm) = http_bytes(addr, "GET", &results_path, "");
        assert_eq!(status, 200);
        assert_eq!(warm, cold, "warm results must be byte-identical to the cold assembly");
    }
    let warm_ms = t0.elapsed().as_secs_f64() * 1e3 / warm_reqs as f64;
    let results_speedup = cold_ms / warm_ms;
    println!(
        "results: cold {cold_ms:.2} ms, warm {warm_ms:.2} ms over {warm_reqs} reqs \
         ({} KB body) -> {results_speedup:.1}x",
        cold.len() / 1024
    );
    // the server's instrumentation must agree: one cold assembly, all
    // other GETs warm, and each of the 16 cell files parsed exactly once
    let (status, health) = http(addr, "GET", "/healthz", "");
    assert_eq!(status, 200);
    assert_eq!(get_usize(&health, "results_cold"), 1, "exactly one cold assembly: {health}");
    assert_eq!(get_usize(&health, "results_warm"), warm_reqs, "{health}");
    assert_eq!(get_usize(&health, "doc_parses"), CELLS, "parse-once violated: {health}");

    // resubmission: idempotent id, zero new executions
    let (status, doc) = http(addr, "POST", "/jobs", SUBMIT);
    assert_eq!(status, 200, "resubmission of a known job: {doc}");
    assert_eq!(doc.get("job").and_then(|j| j.as_str()), Some(job.as_str()));
    assert_eq!(get_usize(&doc, "executed"), CELLS, "resubmission executes nothing new");

    let (status, _) = http(addr, "POST", "/shutdown", "{}");
    assert_eq!(status, 200);
    handle.join().expect("server thread");

    let record = Value::object(vec![
        ("bench", Value::from("serve_http")),
        ("cells", Value::from(CELLS)),
        ("rows", Value::from(rows)),
        ("healthz_requests", Value::from(reqs)),
        ("healthz_us_per_request", Value::from(us_per_req)),
        ("sweep_ms", Value::from(sweep_ms)),
        ("workers", Value::from(2usize)),
        ("store", Value::from(store_dir)),
    ]);
    match append_bench_record(record) {
        Ok(path) => println!("recorded serve smoke to {}", path.display()),
        Err(e) => eprintln!("warning: could not append bench record: {e}"),
    }
    // the results read path as a gateable kernel record: CI holds the
    // warm/cold speedup to a floor via
    //   bench-report --kernel raw_doc_results --floor 2.0
    let record = Value::object(vec![
        ("bench", Value::from("serve_http")),
        ("kernel", Value::from("raw_doc_results")),
        ("backend", Value::from("raw_doc")),
        ("elems", Value::from(CELLS)),
        ("cold_ms", Value::from(cold_ms)),
        ("warm_ms", Value::from(warm_ms)),
        ("warm_requests", Value::from(warm_reqs)),
        ("body_bytes", Value::from(cold.len())),
        ("speedup", Value::from(results_speedup)),
    ]);
    match append_bench_record(record) {
        Ok(path) => println!("recorded raw_doc_results speedup to {}", path.display()),
        Err(e) => eprintln!("warning: could not append bench record: {e}"),
    }
}

//! Extension ablation: gradient bit-width sweep of the
//! quantization-error/accuracy trade-off under in-hindsight ranges — the
//! paper fixes 8 bits for the accuracy tables; this maps the headroom
//! below it.  The bit-width axis is a brace-expanded scheme grid
//! (`g:hindsight:{2,4,6,8,10}`); each expanded row is a full
//! mixed-precision `QuantScheme` driving the quant substrate (error
//! metrics) and the simulator's scheme bridge (per-class-bit backward
//! traffic); every row is appended to `BENCH_kernels.json` so the
//! mixed-precision trajectory accumulates.
//!
//!   cargo bench --bench ablation_bitwidth

use hindsight::coordinator::GridSpec;
use hindsight::quant::{self, QuantParams};
use hindsight::simulator::scheme::layer_traffic;
use hindsight::simulator::traffic;
use hindsight::util::bench::{append_bench_record, Table};
use hindsight::util::json::Value;
use hindsight::util::rng::Pcg32;

fn main() {
    // gradient-like tensor: gaussian bulk + mild heavy tail
    let mut rng = Pcg32::new(9, 1);
    let g: Vec<f32> = (0..262_144)
        .map(|i| {
            let x = rng.normal() * 0.02;
            if i % 701 == 0 {
                x * 8.0
            } else {
                x
            }
        })
        .collect();
    let (lo, hi) = quant::minmax(&g);
    // hindsight-style range: 10% EMA lag on the true extrema
    let (hlo, hhi) = (lo * 0.9, hi * 0.9);
    let layer = traffic::table5_layers()[0];

    let mut t = Table::new(
        "Ablation — gradient bit-width sweep (gradient-shaped tensor, hindsight range)",
        &["scheme", "MSE", "cosine", "saturation", "bwd static KB", "step ratio"],
    );
    // one mixed-precision scheme per row, brace-expanded by the grid
    // engine (seed axis unused: these rows run on the simulator, not
    // the trainer)
    let grid = GridSpec::new("w:current:8 a:hindsight:8 g:hindsight:{2,4,6,8,10}", &[1])
        .expect("bit-width grid");
    for scheme in grid.schemes() {
        let bits = scheme.gradients.bits;
        let qp = QuantParams::from_range(hlo, hhi, bits);
        let q: Vec<f32> = g.iter().map(|&x| qp.fq(x)).collect();
        let mse = quant::mse(&g, hlo, hhi, bits);
        let cos = quant::cosine_similarity(&g, &q);
        let sat = quant::saturation_ratio(&g, hlo, hhi);
        // per-class bits flow through the simulator's scheme bridge
        let lt = layer_traffic(scheme, &layer);
        let bwd_static_kb = lt.bwd.static_bits as f64 / 8.0 / 1024.0;
        t.row(&[
            scheme.to_string(),
            format!("{mse:.3e}"),
            format!("{cos:.5}"),
            format!("{sat:.4}"),
            format!("{bwd_static_kb:.0}"),
            format!("{:.2}", lt.step_ratio()),
        ]);
        let record = Value::object(vec![
            ("bench", Value::from("ablation_bitwidth")),
            ("scheme", Value::from(scheme.to_string())),
            ("bits_g", Value::from(bits as usize)),
            ("mse", Value::from(mse)),
            ("cosine", Value::from(cos as f64)),
            ("bwd_static_kb", Value::from(bwd_static_kb)),
            ("step_ratio", Value::from(lt.step_ratio())),
        ]);
        match append_bench_record(record) {
            Ok(path) => log::debug!("recorded bitwidth row to {}", path.display()),
            Err(e) => eprintln!("warning: could not append bench record: {e}"),
        }
    }
    t.print();
    println!(
        "cosine (DSGC's objective) saturates by 8 bits — consistent with the \
         paper's choice of G8 and with 4-bit work needing format changes \
         (radix-4 FP4, Sun et al. 2020); lower G bits also *widen* the \
         static-vs-dynamic step ratio (the dynamic 32-bit round trip is fixed)."
    );
}

//! Extension ablation: bit-width sweep of the quantization-error/accuracy
//! trade-off under in-hindsight ranges — the paper fixes 8 bits for the
//! accuracy tables; this maps the headroom below it using the Rust quant
//! substrate (error metrics) plus the simulator (traffic scaling).
//!
//!   cargo bench --bench ablation_bitwidth

use hindsight::quant::{self, QuantParams};
use hindsight::simulator::traffic::{self, BitWidths};
use hindsight::util::bench::Table;
use hindsight::util::rng::Pcg32;

fn main() {
    // gradient-like tensor: gaussian bulk + mild heavy tail
    let mut rng = Pcg32::new(9, 1);
    let g: Vec<f32> = (0..262_144)
        .map(|i| {
            let x = rng.normal() * 0.02;
            if i % 701 == 0 {
                x * 8.0
            } else {
                x
            }
        })
        .collect();
    let (lo, hi) = quant::minmax(&g);
    // hindsight-style range: 10% EMA lag on the true extrema
    let (hlo, hhi) = (lo * 0.9, hi * 0.9);

    let mut t = Table::new(
        "Ablation — bit-width sweep (gradient-shaped tensor, hindsight range)",
        &["bits", "MSE", "cosine", "saturation", "traffic (Table5 row1, static KB)"],
    );
    for bits in [2u32, 4, 6, 8, 10] {
        let qp = QuantParams::from_range(hlo, hhi, bits);
        let q: Vec<f32> = g.iter().map(|&x| qp.fq(x)).collect();
        let mse = quant::mse(&g, hlo, hhi, bits);
        let cos = quant::cosine_similarity(&g, &q);
        let sat = quant::saturation_ratio(&g, hlo, hhi);
        let b = BitWidths {
            b_w: bits as u64,
            b_a: bits as u64,
            b_acc: 32,
        };
        let cost = traffic::compare(&traffic::table5_layers()[0], b);
        t.row(&[
            bits.to_string(),
            format!("{mse:.3e}"),
            format!("{cos:.5}"),
            format!("{:.4}", sat),
            format!("{:.0}", cost.static_kb()),
        ]);
    }
    t.print();
    println!(
        "cosine (DSGC's objective) saturates by 8 bits — consistent with the \
         paper's choice of G8 and with 4-bit work needing format changes \
         (radix-4 FP4, Sun et al. 2020)."
    );
}

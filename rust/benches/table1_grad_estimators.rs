//! Paper Table 1: gradient-quantization range-estimator comparison.
//! Forward pass FP32, activation gradients quantized to 8 bits with
//! stochastic rounding; ResNet-family model, multi-seed val accuracy.
//!
//!   cargo bench --bench table1_grad_estimators

mod common;

use common::{estimator_table, Mode};

fn main() {
    hindsight::util::logging::init();
    let paper = [
        ("FP32", "58.97 ± 0.13"),
        ("Current min-max", "59.14 ± 0.23"),
        ("Running min-max", "59.25 ± 0.55"),
        ("DSGC", "59.35 ± 0.95"),
        ("In-hindsight min-max", "59.46 ± 0.71"),
    ];
    let table = estimator_table(
        "Table 1 — gradient quantization range estimators \
         (ResNet-tiny / SynthTiny, G8, fwd FP32)",
        "resnet_tiny",
        Mode::GradOnly,
        &paper,
    );
    table.print();
    println!(
        "shape check: paper finds all estimators within ~0.5% of FP32 with \
         in-hindsight on par or better; absolute values differ (synthetic data)."
    );
    common::assert_rows_close_to_fp32(&table, 20.0);
}

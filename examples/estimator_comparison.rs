//! Range-estimator comparison at a glance (a fast, single-seed version of
//! the paper's Table 1/2/3 protocol) over the *whole* estimator registry
//! — the paper's five plus the literature plugins (max-history, sampled
//! min-max) — plus the range-trajectory view that motivates in-hindsight
//! estimation: how each estimator's range state tracks the true (current
//! min-max) statistics over training.
//!
//!   cargo run --release --example estimator_comparison

use anyhow::Result;
use hindsight::coordinator::{Estimator, TrainConfig, Trainer};
use hindsight::runtime::Engine;
use hindsight::util::bench::{env_usize, Table};

fn main() -> Result<()> {
    hindsight::util::logging::init();
    let steps = env_usize("HINDSIGHT_CMP_STEPS", 120) as u64;
    let engine = Engine::new()?;

    let mut table = Table::new(
        "Estimator comparison (cnn, fully quantized, 1 seed, full registry)",
        &["Method", "Static", "Val acc (%)", "Train s"],
    );
    for est in Estimator::all() {
        // fully_quantized applies the search-estimator act fallback
        // (gradients searched, activations current min-max)
        let mut cfg = TrainConfig::new("cnn").fully_quantized(est);
        cfg.steps = steps;
        cfg.n_train = 1024;
        cfg.n_val = 256;
        cfg.seed = 3;
        let rec = Trainer::new(&engine, cfg)?.run()?;
        table.row(&[
            est.name().to_string(),
            if est.enabled() {
                if est.is_static() { "yes".into() } else { "no".into() }
            } else {
                "n.a.".into()
            },
            format!("{:.2}", rec.final_val_acc()),
            format!("{:.1}", rec.train_seconds),
        ]);
    }
    table.print();

    // range trajectory: quantize gradients with hindsight and log how the
    // EMA state trails the per-step statistics (site 0's grad quantizer)
    println!("\nrange trajectory (first grad site, in-hindsight vs stats):");
    let mut cfg = TrainConfig::new("cnn").grad_only(Estimator::HINDSIGHT);
    cfg.steps = 40;
    cfg.n_train = 512;
    let mut t = Trainer::new(&engine, cfg)?;
    let site = t
        .ranges
        .search_sites()
        .first()
        .copied()
        .unwrap_or(1); // any grad site; search_sites is empty for hindsight
    let site = if t.ranges.n_sites() > 1 { 1 } else { site };
    for step in 0..40u64 {
        t.train_step()?;
        if step % 8 == 0 {
            let r = t.ranges.row(site);
            let s = t.ranges.last_stats(site);
            println!(
                "  step {step:>3}: range [{:+.4}, {:+.4}]  stats [{:+.4}, {:+.4}]",
                r[0], r[1], s[0], s[1]
            );
        }
    }
    Ok(())
}

//! Quickstart: train a small CNN with fully quantized W8/A8/G8 training
//! using the paper's in-hindsight min-max range estimation.
//!
//!   make artifacts && cargo run --release --example quickstart
//!
//! The `cnn` artifact lowers its quantizers through the L1 Pallas kernel
//! (`pallas=all`), so this exercises all three layers of the stack:
//! Pallas kernel -> JAX graph -> Rust coordinator.

use anyhow::Result;
use hindsight::coordinator::{Estimator, TrainConfig, Trainer};
use hindsight::runtime::Engine;

fn main() -> Result<()> {
    hindsight::util::logging::init();

    let engine = Engine::new()?;
    let mut cfg = TrainConfig::new("cnn").fully_quantized(Estimator::HINDSIGHT);
    cfg.steps = 60;
    cfg.n_train = 1024;
    cfg.n_val = 256;
    cfg.lr = 0.05;
    cfg.seed = 1;

    println!("== hindsight quickstart: cnn, W8/A8/G8, in-hindsight min-max ==");
    let mut trainer = Trainer::new(&engine, cfg)?;
    trainer.calibrate()?;
    for step in 0..60u64 {
        let (loss, acc) = trainer.train_step()?;
        if step % 10 == 0 {
            println!("step {step:>3}  loss {loss:.4}  batch acc {acc:.3}");
        }
    }
    let (val_loss, val_acc) = trainer.evaluate()?;
    println!("validation: loss {val_loss:.4}  acc {:.1}%", val_acc * 100.0);

    // the in-hindsight state the coordinator carried between steps:
    println!("\nper-site ranges after training (first 4 sites):");
    for i in 0..4.min(trainer.ranges.n_sites()) {
        let r = trainer.ranges.row(i);
        let s = trainer.ranges.last_stats(i);
        println!(
            "  site {i}: range [{:+.3}, {:+.3}]  last stats [{:+.3}, {:+.3}]",
            r[0], r[1], s[0], s[1]
        );
    }
    Ok(())
}

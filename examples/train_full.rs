//! End-to-end driver (EXPERIMENTS.md §E2E): fully quantized W8/A8/G8
//! training of the ResNet-family model on the SynthTiny workload for a
//! few hundred steps, with periodic evaluation, a logged loss curve and a
//! final FP32-vs-quantized comparison — the full three-layer system on a
//! real small workload.
//!
//!   cargo run --release --example train_full
//!
//! Env: HINDSIGHT_E2E_STEPS (default 300), HINDSIGHT_E2E_MODEL
//! (default resnet_tiny).

use anyhow::Result;
use hindsight::coordinator::{Estimator, Schedule, TrainConfig, Trainer};
use hindsight::runtime::Engine;
use hindsight::util::bench::env_usize;

fn cfg(model: &str, steps: u64, est: Estimator) -> TrainConfig {
    let mut c = TrainConfig::new(model).fully_quantized(est);
    c.steps = steps;
    c.n_train = 2048;
    c.n_val = 512;
    c.lr = 0.05;
    c.schedule = Schedule::Cosine;
    c.eval_every = steps / 4;
    c.seed = 7;
    c
}

fn main() -> Result<()> {
    hindsight::util::logging::init();
    let steps = env_usize("HINDSIGHT_E2E_STEPS", 300) as u64;
    let model = std::env::var("HINDSIGHT_E2E_MODEL")
        .unwrap_or_else(|_| "resnet_tiny".to_string());

    println!("== end-to-end: {model}, {steps} steps, SynthTiny ==");
    let engine = Engine::new()?;

    println!("\n-- in-hindsight W8/A8/G8 --");
    let rec_q = Trainer::new(&engine, cfg(&model, steps, Estimator::HINDSIGHT))?
        .run()?;
    println!("\n-- FP32 baseline --");
    let rec_fp = Trainer::new(&engine, cfg(&model, steps, Estimator::FP32))?
        .run()?;

    println!("\nloss curve (quantized run):");
    let n = rec_q.steps.len();
    for i in (0..n).step_by((n / 12).max(1)) {
        let bar = "#".repeat((rec_q.losses[i] * 18.0).min(60.0) as usize);
        println!("  step {:>4}  {:<7.4} {bar}", rec_q.steps[i], rec_q.losses[i]);
    }
    println!("\nevals (quantized): {:?}", rec_q.evals);

    println!("\n== summary ==");
    println!(
        "  FP32        : val acc {:.2}%  ({:.1}s)",
        rec_fp.final_val_acc(),
        rec_fp.train_seconds
    );
    println!(
        "  in-hindsight: val acc {:.2}%  ({:.1}s)",
        rec_q.final_val_acc(),
        rec_q.train_seconds
    );
    println!(
        "  gap: {:+.2}%  (paper: within ~0.5% of FP32)",
        rec_q.final_val_acc() - rec_fp.final_val_acc()
    );

    assert!(
        rec_q.loss_decreased(),
        "quantized training loss did not decrease — e2e failure"
    );
    rec_q.write_csv("runs_e2e_quantized.csv").ok();
    rec_fp.write_csv("runs_e2e_fp32.csv").ok();
    println!("\nloss curves: runs_e2e_quantized.csv, runs_e2e_fp32.csv");
    Ok(())
}

//! Memory-movement report (paper Sec. 6): static vs dynamic quantization
//! traffic for every conv layer of the full-size ImageNet architectures,
//! cross-checked against the cycle-approximate MAC-array machine.
//!
//!   cargo run --release --example memory_report

use anyhow::Result;
use hindsight::models;
use hindsight::simulator::machine::MacArray;
use hindsight::simulator::traffic::{self, BitWidths};
use hindsight::util::bench::Table;

fn main() -> Result<()> {
    let b = BitWidths::default();
    let mac = MacArray::default();

    for net in ["resnet18", "vgg16", "mobilenet_v2"] {
        let layers = models::by_name(net).unwrap();
        let mut t = Table::new(
            &format!("{net} — per-layer memory movement (b_w=b_a=8, b_acc=32)"),
            &["Layer", "Geometry", "MACs", "Static KB", "Dynamic KB", "Delta"],
        );
        let mut tot_s = 0u64;
        let mut tot_d = 0u64;
        let mut worst = (0.0f64, "");
        for g in &layers {
            let c = traffic::compare(g, b);
            // cross-check against the machine-level accounting
            let ph_s = mac.conv_traffic(g, true);
            let ph_d = mac.conv_traffic(g, false);
            assert_eq!(ph_s.total() * 8, c.static_bits);
            assert_eq!(ph_d.total() * 8, c.dynamic_bits);
            tot_s += c.static_bits;
            tot_d += c.dynamic_bits;
            if c.ratio() > worst.0 {
                worst = (c.ratio(), g.name);
            }
            t.row(&[
                g.name.to_string(),
                format!(
                    "{}x{}x{}, {}x{}{}",
                    g.cin,
                    g.w,
                    g.h,
                    g.k,
                    g.k,
                    if g.depthwise { " dw" } else { "" }
                ),
                format!("{:.1}M", g.macs() as f64 / 1e6),
                format!("{:.0}", c.static_kb()),
                format!("{:.0}", c.dynamic_kb()),
                format!("+{:.0}%", c.delta_percent()),
            ]);
        }
        t.print();
        println!(
            "  network total: static {:.1} MB, dynamic {:.1} MB (+{:.0}%); worst layer: {} at {:.1}x",
            tot_s as f64 / 8e6,
            tot_d as f64 / 8e6,
            (tot_d as f64 / tot_s as f64 - 1.0) * 100.0,
            worst.1,
            worst.0,
        );
    }

    println!(
        "\npaper headline check: max dynamic/static ratio across MobileNetV2 = {:.1}x (paper: up to 8x)",
        models::by_name("mobilenet_v2")
            .unwrap()
            .iter()
            .map(|g| traffic::compare(g, b).ratio())
            .fold(0.0, f64::max)
    );
    Ok(())
}

"""AOT lowering: L2 graphs -> HLO text + manifest.json (the Rust ABI).

Run once via ``make artifacts``; the Rust binary is self-contained after.

Interchange format is HLO **text**, not serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which the runtime's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

The manifest records, per model, the flat input/output layout of every
graph so the Rust runtime can marshal buffers positionally, plus the
quantizer-site table the coordinator's RangeManager is keyed on.

Artifact matrix (see DESIGN.md §3 for the sizing rationale):

  model           size knobs                     pallas  graphs
  --------------- ------------------------------ ------- --------------------
  mlp             8x8x3, 10 classes, bs 32       all     init/train/eval/dump
  cnn             32x32x3, 16 classes, bs 32     all     init/train/eval/dump
  resnet_tiny     widths (8,16,32,64), bs 32     none    init/train/eval/dump
  vgg_tiny        plan ((8,8),(16,16),(32,32))   none    init/train/eval/dump
  mobilenet_tiny  16x16x3, bs 16                 none    init/train/eval/dump
  resnet_pallas   = resnet_tiny                  grad    init/train

"pallas none/grad/all" selects which quantizer sites lower through the L1
Pallas kernel vs the bit-identical jnp oracle (property-tested equal): the
interpret-mode Pallas path costs ~3x CPU wall-clock per site, so the
multi-seed table sweeps use the oracle lowering while mlp/cnn (the
quickstart/e2e artifacts) and resnet_pallas carry the kernel end-to-end.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import models, train, quant_ops as qo

SPECS = {
    "mlp": dict(builder="mlp", kw=dict(n_classes=10, hw=8), batch=32,
                pallas="all", graphs=("init", "train", "eval", "dump")),
    "cnn": dict(builder="cnn", kw=dict(n_classes=16, hw=32), batch=32,
                pallas="all", graphs=("init", "train", "eval", "dump")),
    "resnet_tiny": dict(builder="resnet_tiny",
                        kw=dict(n_classes=16, hw=32, widths=(8, 16, 32, 64),
                                blocks=(1, 1, 1, 1)),
                        batch=32, pallas="none",
                        graphs=("init", "train", "eval", "dump")),
    "vgg_tiny": dict(builder="vgg_tiny",
                     kw=dict(n_classes=16, hw=32,
                             plan=((8, 8), (16, 16), (32, 32))),
                     batch=32, pallas="none",
                     graphs=("init", "train", "eval", "dump")),
    "mobilenet_tiny": dict(builder="mobilenet_tiny",
                           kw=dict(n_classes=16, hw=16), batch=16,
                           pallas="none",
                           graphs=("init", "train", "eval", "dump")),
    # kernel-at-scale variant for perf/ablation benches
    "resnet_pallas": dict(builder="resnet_tiny",
                          kw=dict(n_classes=16, hw=32,
                                  widths=(8, 16, 32, 64),
                                  blocks=(1, 1, 1, 1)),
                          batch=32, pallas="grad", graphs=("init", "train")),
}


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def _dt(x):
    return {"float32": "f32", "int32": "i32"}[str(x.dtype)]


def _io_spec(names, arrays):
    assert len(names) == len(arrays), (len(names), len(arrays))
    return [{"name": n, "shape": [int(d) for d in a.shape], "dtype": _dt(a)}
            for n, a in zip(names, arrays)]


def _graph_entry(out_dir, tag, fn, example, in_names, out_names):
    # keep_unused: the manifest ABI is positional — jit must not prune
    # arguments that a particular graph happens not to read.
    lowered = jax.jit(fn, keep_unused=True).lower(*example)
    text = to_hlo_text(lowered)
    fname = f"{tag}.hlo.txt"
    with open(os.path.join(out_dir, fname), "w") as f:
        f.write(text)
    outs = jax.eval_shape(fn, *example)
    outs_flat = list(outs) if isinstance(outs, tuple) else [outs]
    return {
        "file": fname,
        "inputs": _io_spec(in_names, example),
        "outputs": _io_spec(out_names, outs_flat),
    }


def lower_model(out_dir: str, name: str, spec) -> dict:
    model = models.build(spec["builder"], **spec["kw"])
    cfg = qo.QuantConfig(use_pallas=spec["pallas"])
    bs = spec["batch"]
    P = [p.name for p in model.reg.params]
    S = [s.name for s in model.reg.state]
    gsites = [s for s in model.reg.sites if s.kind == "grad"]

    entry = {
        "batch_size": bs,
        "input_shape": list(model.input_shape),
        "n_classes": model.n_classes,
        "n_params": int(model.n_params),
        "pallas": spec["pallas"],
        "params": [{"name": p.name, "shape": list(p.shape)}
                   for p in model.reg.params],
        "state": [{"name": s.name, "shape": list(s.shape)}
                  for s in model.reg.state],
        "sites": [{"index": s.index, "name": s.name, "kind": s.kind,
                   "feature_shape": list(s.feature_shape)}
                  for s in model.reg.sites],
        "graphs": {},
    }

    scalars_train = ["mode_act", "mode_grad", "wq_on", "aq_on", "gq_on",
                     "eta", "lr", "wd", "seed"]

    if "init" in spec["graphs"]:
        fn, ex = train.make_init(model)
        entry["graphs"]["init"] = _graph_entry(
            out_dir, f"{name}_init", fn, ex, ["seed"],
            [f"param:{n}" for n in P] + [f"opt:{n}" for n in P]
            + [f"state:{n}" for n in S])

    if "train" in spec["graphs"]:
        fn, ex = train.make_train_step(model, bs, cfg)
        in_names = ([f"param:{n}" for n in P] + [f"opt:{n}" for n in P]
                    + [f"state:{n}" for n in S]
                    + ["x", "y", "ranges"] + scalars_train)
        out_names = ([f"param:{n}" for n in P] + [f"opt:{n}" for n in P]
                     + [f"state:{n}" for n in S]
                     + ["loss", "acc", "new_ranges", "stats"])
        entry["graphs"]["train"] = _graph_entry(
            out_dir, f"{name}_train", fn, ex, in_names, out_names)

    if "eval" in spec["graphs"]:
        fn, ex = train.make_eval_step(model, bs, cfg)
        in_names = ([f"param:{n}" for n in P] + [f"state:{n}" for n in S]
                    + ["x", "y", "ranges", "mode_act", "wq_on", "aq_on"])
        entry["graphs"]["eval"] = _graph_entry(
            out_dir, f"{name}_eval", fn, ex, in_names,
            ["loss_sum", "correct"])

    if "dump" in spec["graphs"]:
        fn, ex = train.make_dump_step(model, bs, cfg)
        in_names = ([f"param:{n}" for n in P] + [f"state:{n}" for n in S]
                    + ["x", "y", "ranges", "mode_grad", "wq_on", "aq_on",
                       "gq_on", "eta", "seed"])
        entry["graphs"]["dump"] = _graph_entry(
            out_dir, f"{name}_dump", fn, ex, in_names,
            [f"grad:{s.name}" for s in gsites])

    return entry


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--only", default=None,
                    help="comma-separated model subset (for development)")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    names = list(SPECS) if args.only is None else args.only.split(",")
    manifest = {"version": 1, "quant": {"bits_w": 8, "bits_a": 8,
                                        "bits_g": 8},
                "models": {}}
    for name in names:
        print(f"[aot] lowering {name} ...", flush=True)
        manifest["models"][name] = lower_model(args.out, name, SPECS[name])

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] wrote {args.out}/manifest.json "
          f"({len(manifest['models'])} models)")


if __name__ == "__main__":
    main()

"""L2 quantization operations: estimator mode-switching, STE, gradient taps.

This module wires the L1 kernels into the training graph and implements the
paper's range-estimation semantics *in-graph*, selected by a runtime scalar
so a single AOT artifact serves every estimator:

  mode 0 — current min-max   (dynamic): quantize with minmax(G^t)
  mode 1 — running min-max   (dynamic): quantize with
                                        (1-eta)*minmax(G^t) + eta*range^{t-1}
  mode 2 — in-hindsight      (static) : quantize with range^{t-1}  (paper)

For every mode the graph also emits, per quantizer site,

  stats[q]      = minmax of the *pre-quantization* tensor at step t
                  (the accumulator statistics of Fig. 3), and
  new_ranges[q] = the range state to carry to step t+1:
                  current   -> stats
                  running   -> (1-eta)*stats + eta*prev   (blended, = used)
                  hindsight -> (1-eta)*stats + eta*prev   (paper eqs. 2-3)

Note running and hindsight share the state-update rule; they differ only in
whether the *current* step's quantizer gets to see the current statistics
(dynamic) or not (static).  DSGC runs as mode 2 with the coordinator
overriding the range state from its periodic golden-section search.

Gradient quantization happens inside the backward pass, where a functional
graph cannot emit extra primal outputs.  We use the *dummy-cotangent trick*:
each gradient site takes a zero (2,2) dummy input whose custom-VJP cotangent
is defined to be [stats; new_ranges] — ``jax.grad`` w.r.t. the dummies then
delivers the backward-pass statistics as ordinary outputs.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from .kernels import fake_quant as fq_kernel
from .kernels import ref

MODE_CURRENT = 0
MODE_RUNNING = 1
MODE_HINDSIGHT = 2

MODE_NAMES = {"current": MODE_CURRENT, "running": MODE_RUNNING,
              "hindsight": MODE_HINDSIGHT}


class QuantConfig(NamedTuple):
    """Static quantization configuration baked into a model graph."""
    bits_w: int = 8
    bits_a: int = 8
    bits_g: int = 8
    # which sites go through the Pallas kernel ("all" | "grad" | "none");
    # the others use the jnp oracle (identical numerics, cheaper HLO).
    use_pallas: str = "all"


class QuantCtx(NamedTuple):
    """Runtime quantization inputs threaded through ``apply``.

    All fields are traced values (graph inputs); ``ranges`` is the (Q, 2)
    range state, modes/enables are f32 scalars (f32 so that custom-VJP
    cotangent types stay uniform).
    """
    ranges: jax.Array       # (Q, 2)
    mode_act: jax.Array     # f32 scalar in {0,1,2}
    mode_grad: jax.Array    # f32 scalar in {0,1,2}
    wq_on: jax.Array        # f32 scalar in {0,1}
    aq_on: jax.Array
    gq_on: jax.Array
    eta: jax.Array          # EMA momentum (paper: 0.9)
    key: jax.Array          # PRNG key for stochastic rounding
    cfg: QuantConfig        # static
    tap: object = None      # grad_tap (train) or dump_tap (DSGC dump graph)


def _resolve_ranges(mode_i32, prev, stats, eta):
    """Range used *now* per estimator mode (see module docstring).

    Arithmetic select rather than ``lax.switch``: the statistics are
    computed unconditionally anyway (they are a graph output for every
    mode), the candidates are 2-element tensors, and conditionals at
    ~200 sites made ancient XLA versions' compile times explode (347s ->
    seconds on the runtime's xla_extension 0.5.1).
    """
    blended = ref.ema_update(prev, stats, eta)
    return jnp.where(mode_i32 == 0, stats,
                     jnp.where(mode_i32 == 1, blended, prev))


def _next_ranges(mode_i32, prev, stats, eta):
    """Range state carried to the next step per estimator mode."""
    blended = ref.ema_update(prev, stats, eta)
    # current min-max keeps no real state; running/hindsight: eqs. 2-3
    return jnp.where(mode_i32 == 0, stats, blended)


def _fake_quant(x, ranges, bits, noise, via_pallas):
    if via_pallas:
        return fq_kernel.fake_quant_with_stats(x, ranges, noise, bits=bits)
    return ref.fake_quant_with_stats(x, ranges, bits=bits, noise=noise)


def weight_quant(w, ctx: QuantCtx):
    """Paper Sec. 5.2: weights always use *current* min-max, nearest
    rounding, straight-through estimator; gated by ``wq_on``."""
    w_sg = lax.stop_gradient(w)
    r = ref.minmax(w_sg)
    # the kernel sees only stop_gradient'ed values: pallas_call has no JVP
    # rule, and the STE below re-injects the identity gradient anyway.
    wq, _ = _fake_quant(w_sg, r, ctx.cfg.bits_w, None,
                        ctx.cfg.use_pallas == "all")
    wq = jnp.where(ctx.wq_on > 0.5, wq, w_sg)
    return w + lax.stop_gradient(wq - w)


def act_quant(x, site: int, ctx: QuantCtx):
    """Activation quantizer site (forward; the Q_Y of Fig. 1).

    Returns ``(x_q, stats, new_range)``; straight-through gradient.
    """
    prev = ctx.ranges[site]
    mode = ctx.mode_act.astype(jnp.int32)
    x_sg = lax.stop_gradient(x)
    stats = ref.minmax(x_sg)
    used = _resolve_ranges(mode, prev, stats, ctx.eta)
    xq, _ = _fake_quant(x_sg, used, ctx.cfg.bits_a, None,
                        ctx.cfg.use_pallas == "all")
    xq = jnp.where(ctx.aq_on > 0.5, xq, x_sg)
    out = x + lax.stop_gradient(xq - x)
    new_range = _next_ranges(mode, prev, stats, ctx.eta)
    return out, stats, new_range


# ---------------------------------------------------------------------------
# Gradient tap: quantize the input-gradient G_X inside the backward pass.
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _grad_tap(bits_and_pallas, x, dummy, prev, mode_f, eta, gq_on, noise):
    """Identity on ``x``; backward quantizes the cotangent (G_X).

    ``dummy`` is a (2, 2) zeros input; its cotangent is defined as
    ``[stats; new_ranges]`` so the caller can extract backward statistics
    via ``jax.grad``.  ``noise`` (x-shaped uniforms) drives stochastic
    rounding (paper Sec. 5.2 quantizes gradients stochastically).
    """
    del dummy, prev, mode_f, eta, gq_on, noise
    return x


def _grad_tap_fwd(bits_and_pallas, x, dummy, prev, mode_f, eta, gq_on, noise):
    del dummy
    return x, (prev, mode_f, eta, gq_on, noise)


def _grad_tap_bwd(bits_and_pallas, res, g):
    bits, via_pallas = bits_and_pallas
    prev, mode_f, eta, gq_on, noise = res
    mode = mode_f.astype(jnp.int32)

    stats = ref.minmax(g)
    used = _resolve_ranges(mode, prev, stats, eta)
    gq, _ = _fake_quant(g, used, bits, noise, via_pallas)
    gq = jnp.where(gq_on > 0.5, gq, g)
    new_range = _next_ranges(mode, prev, stats, eta)

    packed = jnp.stack([stats, new_range])  # (2, 2) -> dummy cotangent
    zeros2 = jnp.zeros(2, jnp.float32)
    zf = jnp.zeros((), jnp.float32)
    return (gq, packed, zeros2, zf, zf, zf, jnp.zeros_like(noise))


_grad_tap.defvjp(_grad_tap_fwd, _grad_tap_bwd)


def grad_tap(x, dummy, site: int, ctx: QuantCtx):
    """Place a gradient quantizer (Q_G of Fig. 1) on tensor ``x``.

    Forward identity; the cotangent flowing back through ``x`` — the input
    gradient G_X propagated to the preceding layer — is quantized per
    ``ctx.mode_grad``.  ``dummy`` must be ``jnp.zeros((2, 2))``; its
    gradient carries ``[stats; new_ranges]`` for this site.
    """
    noise = jax.random.uniform(jax.random.fold_in(ctx.key, site), x.shape)
    via_pallas = ctx.cfg.use_pallas in ("all", "grad")
    return _grad_tap((ctx.cfg.bits_g, via_pallas), x, dummy,
                     ctx.ranges[site], ctx.mode_grad, ctx.eta, ctx.gq_on,
                     noise)


# ---------------------------------------------------------------------------
# Dump tap: DSGC support — emit the raw FP gradient tensor of a site.
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _dump_tap(bits_and_pallas, x, dummy, prev, mode_f, eta, gq_on, noise):
    """Like ``_grad_tap`` but ``dummy`` is x-shaped and its cotangent is the
    *raw* (pre-quantization) gradient tensor — the expensive full-tensor
    readback DSGC's periodic range search requires (paper Sec. 5.1)."""
    del dummy, prev, mode_f, eta, gq_on, noise
    return x


def _dump_tap_fwd(bits_and_pallas, x, dummy, prev, mode_f, eta, gq_on, noise):
    del dummy
    return x, (prev, mode_f, eta, gq_on, noise)


def _dump_tap_bwd(bits_and_pallas, res, g):
    bits, via_pallas = bits_and_pallas
    prev, mode_f, eta, gq_on, noise = res
    mode = mode_f.astype(jnp.int32)
    stats = ref.minmax(g)
    used = _resolve_ranges(mode, prev, stats, eta)
    gq, _ = _fake_quant(g, used, bits, noise, via_pallas)
    gq = jnp.where(gq_on > 0.5, gq, g)
    zeros2 = jnp.zeros(2, jnp.float32)
    zf = jnp.zeros((), jnp.float32)
    return (gq, g, zeros2, zf, zf, zf, jnp.zeros_like(noise))


_dump_tap.defvjp(_dump_tap_fwd, _dump_tap_bwd)


def dump_tap(x, dummy, site: int, ctx: QuantCtx):
    """DSGC variant of ``grad_tap``: ``dummy`` is x-shaped; its gradient is
    the raw G_X tensor (quantization still applied to the propagated path)."""
    noise = jax.random.uniform(jax.random.fold_in(ctx.key, site), x.shape)
    via_pallas = ctx.cfg.use_pallas in ("all", "grad")
    return _dump_tap((ctx.cfg.bits_g, via_pallas), x, dummy,
                     ctx.ranges[site], ctx.mode_grad, ctx.eta, ctx.gq_on,
                     noise)

"""L2 mini layer framework with explicit flat parameter layout.

The Rust runtime marshals parameters as a *flat ordered list* of arrays
described by the manifest, so layers declare their parameters explicitly
(name, shape, initializer) instead of relying on pytree introspection.

Conventions:
  * data layout NHWC, weights HWIO (lax.conv_general_dilated defaults for
    these strings);
  * ``params``   — trainable leaves (SGD + momentum applied in-graph);
  * ``state``    — non-trainable leaves (BatchNorm running stats), updated
    by the forward pass during training;
  * quantizer *sites* are registered at model-construction time so the
    (Q, 2) range-state tensor has a static layout the coordinator knows.

Per the paper (Sec. 3.1 / 5.2): weight quantization uses current min-max
with nearest rounding; activation quantizers sit on the feature map a layer
writes to memory; gradient quantizers sit on the input-gradient G_X each
layer propagates backwards; BatchNorm and the weight update stay FP32.
"""

from __future__ import annotations

import math
from typing import Callable, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from . import quant_ops as qo


class ParamSpec(NamedTuple):
    name: str
    shape: Tuple[int, ...]
    init: Callable  # (key, shape) -> array


class SiteSpec(NamedTuple):
    index: int
    name: str
    kind: str          # "act" | "grad"
    # activation shape at the site (batch-independent part), for reporting
    feature_shape: Tuple[int, ...]


class Registry:
    """Collects parameter/state/site specs while a model is constructed."""

    def __init__(self):
        self.params: List[ParamSpec] = []
        self.state: List[ParamSpec] = []
        self.sites: List[SiteSpec] = []

    def add_param(self, name, shape, init) -> int:
        self.params.append(ParamSpec(name, tuple(int(s) for s in shape), init))
        return len(self.params) - 1

    def add_state(self, name, shape, init) -> int:
        self.state.append(ParamSpec(name, tuple(int(s) for s in shape), init))
        return len(self.state) - 1

    def add_site(self, name, kind, feature_shape) -> int:
        idx = len(self.sites)
        self.sites.append(SiteSpec(idx, name, kind,
                                   tuple(int(s) for s in feature_shape)))
        return idx


def _he_normal(fan_in):
    std = math.sqrt(2.0 / fan_in)

    def init(key, shape):
        return jax.random.normal(key, shape) * std
    return init


def _zeros(key, shape):
    del key
    return jnp.zeros(shape, jnp.float32)


def _ones(key, shape):
    del key
    return jnp.ones(shape, jnp.float32)


class Apply(NamedTuple):
    """Closure bundle returned by layer constructors."""
    fn: Callable  # (params, state, x, ctx, train, taps) -> (y, state_updates)


class Model(NamedTuple):
    name: str
    reg: Registry
    apply: Callable   # (pv, sv, x, ctx, train, dummies, collect) -> (logits, new_sv)
    input_shape: Tuple[int, int, int]   # (H, W, C)
    n_classes: int

    @property
    def n_params(self):
        return sum(int(jnp.prod(jnp.array(p.shape))) for p in self.reg.params)


class Collector:
    """Accumulates per-site forward stats/new-ranges during apply."""

    def __init__(self, n_sites):
        self.stats = [None] * n_sites
        self.new_ranges = [None] * n_sites

    def record(self, site, stats, new_range):
        self.stats[site] = stats
        self.new_ranges[site] = new_range


# ---------------------------------------------------------------------------
# Layers.  Each constructor registers params/state/sites on `reg` and
# returns an apply closure over the *indices* it registered.
# ---------------------------------------------------------------------------

def conv2d(reg: Registry, name: str, cin: int, cout: int, k: int,
           stride: int = 1, depthwise: bool = False, use_bias: bool = True,
           grad_site: bool = True, feature_hw: Tuple[int, int] = (0, 0)):
    """Quantized conv layer: weight fake-quant (current min-max) + optional
    gradient tap on its input (quantizes the G_X it back-propagates)."""
    groups = cin if depthwise else 1
    wshape = (k, k, cin // groups, cout)
    wi = reg.add_param(f"{name}.w", wshape, _he_normal(k * k * cin // groups))
    bi = reg.add_param(f"{name}.b", (cout,), _zeros) if use_bias else None
    gsite = (reg.add_site(f"{name}.grad", "grad", (feature_hw[0], feature_hw[1], cin))
             if grad_site else None)

    def fn(pv, sv, x, ctx, train, dummies, collect):
        w = qo.weight_quant(pv[wi], ctx)
        if gsite is not None and train:
            x = ctx.tap(x, dummies[gsite], gsite, ctx)
        y = lax.conv_general_dilated(
            x, w, window_strides=(stride, stride), padding="SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
            feature_group_count=groups)
        if bi is not None:
            y = y + pv[bi]
        return y, []
    return Apply(fn)


def dense(reg: Registry, name: str, cin: int, cout: int,
          grad_site: bool = True):
    wi = reg.add_param(f"{name}.w", (cin, cout), _he_normal(cin))
    bi = reg.add_param(f"{name}.b", (cout,), _zeros)
    gsite = reg.add_site(f"{name}.grad", "grad", (cin,)) if grad_site else None

    def fn(pv, sv, x, ctx, train, dummies, collect):
        w = qo.weight_quant(pv[wi], ctx)
        if gsite is not None and train:
            x = ctx.tap(x, dummies[gsite], gsite, ctx)
        return jnp.matmul(x, w) + pv[bi], []
    return Apply(fn)


def batchnorm(reg: Registry, name: str, c: int, momentum: float = 0.9):
    """FP32 BatchNorm (paper keeps BN out of the quantized path).

    Running stats live in ``state`` and are EMA-updated during training;
    eval uses the running stats.
    """
    gi = reg.add_param(f"{name}.gamma", (c,), _ones)
    bi = reg.add_param(f"{name}.beta", (c,), _zeros)
    mi = reg.add_state(f"{name}.mean", (c,), _zeros)
    vi = reg.add_state(f"{name}.var", (c,), _ones)

    def fn(pv, sv, x, ctx, train, dummies, collect):
        axes = tuple(range(x.ndim - 1))
        if train:
            mean = jnp.mean(x, axis=axes)
            var = jnp.var(x, axis=axes)
            new_mean = momentum * sv[mi] + (1 - momentum) * mean
            new_var = momentum * sv[vi] + (1 - momentum) * var
            updates = [(mi, new_mean), (vi, new_var)]
        else:
            mean, var = sv[mi], sv[vi]
            updates = []
        xn = (x - mean) / jnp.sqrt(var + 1e-5)
        return xn * pv[gi] + pv[bi], updates
    return Apply(fn)


def relu():
    def fn(pv, sv, x, ctx, train, dummies, collect):
        return jax.nn.relu(x), []
    return Apply(fn)


def relu6():
    def fn(pv, sv, x, ctx, train, dummies, collect):
        return jnp.clip(x, 0.0, 6.0), []
    return Apply(fn)


def act_quant(reg: Registry, name: str, feature_shape):
    """Activation quantizer site (the Q_Y the paper estimates ranges for)."""
    site = reg.add_site(f"{name}.act", "act", feature_shape)

    def fn(pv, sv, x, ctx, train, dummies, collect):
        y, stats, new_range = qo.act_quant(x, site, ctx)
        collect.record(site, stats, new_range)
        return y, []
    return Apply(fn)


def maxpool(k: int = 2, stride: int = 2):
    def fn(pv, sv, x, ctx, train, dummies, collect):
        return lax.reduce_window(
            x, -jnp.inf, lax.max, (1, k, k, 1), (1, stride, stride, 1),
            "VALID"), []
    return Apply(fn)


def avgpool_global():
    def fn(pv, sv, x, ctx, train, dummies, collect):
        return jnp.mean(x, axis=(1, 2)), []
    return Apply(fn)


def flatten():
    def fn(pv, sv, x, ctx, train, dummies, collect):
        return x.reshape(x.shape[0], -1), []
    return Apply(fn)


def sequential(layers):
    def fn(pv, sv, x, ctx, train, dummies, collect):
        updates = []
        for layer in layers:
            x, u = layer.fn(pv, sv, x, ctx, train, dummies, collect)
            updates.extend(u)
        return x, updates
    return Apply(fn)


def residual(branch: Apply, shortcut: Optional[Apply] = None):
    """y = branch(x) + shortcut(x) (identity shortcut if None)."""
    def fn(pv, sv, x, ctx, train, dummies, collect):
        y, u1 = branch.fn(pv, sv, x, ctx, train, dummies, collect)
        if shortcut is None:
            s, u2 = x, []
        else:
            s, u2 = shortcut.fn(pv, sv, x, ctx, train, dummies, collect)
        return y + s, u1 + u2
    return Apply(fn)


# ---------------------------------------------------------------------------
# Model assembly helpers
# ---------------------------------------------------------------------------

def finalize(name, reg, top: Apply, input_shape, n_classes) -> Model:
    def apply(pv, sv, x, ctx, train, dummies, collect):
        logits, updates = top.fn(pv, sv, x, ctx, train, dummies, collect)
        new_sv = list(sv)
        for idx, val in updates:
            new_sv[idx] = val
        return logits, new_sv
    return Model(name, reg, apply, input_shape, n_classes)


def init_params(model: Model, key):
    """Materialize params/state per the registry (used by the init graph)."""
    pv = []
    for i, spec in enumerate(model.reg.params):
        pv.append(spec.init(jax.random.fold_in(key, i), spec.shape))
    sv = [spec.init(jax.random.fold_in(key, 10_000 + i), spec.shape)
          for i, spec in enumerate(model.reg.state)]
    return pv, sv

"""Static performance analysis of the AOT artifacts (§Perf, L1/L2).

Because the Pallas kernels run under ``interpret=True`` (CPU correctness
path), wall-clock is not a TPU proxy; the L1/L2 performance deliverables
are *structural*:

  * L2 — HLO op census per artifact: convolution/dot counts must match
    the model's layer count x passes (no duplicate matmuls from the
    fake-quant select paths), fusion-relevant elementwise volume, and
    graph size.
  * L1 — BlockSpec-derived VMEM footprint and MXU-utilization estimates
    for the kernels at the shapes the models actually use.

Usage:  python -m compile.analyze [--artifacts ../artifacts]
"""

from __future__ import annotations

import argparse
import json
import os
import re
from collections import Counter

from .kernels import fake_quant as fq
from .kernels import qmatmul as qm

OPS_OF_INTEREST = (
    "convolution", "dot", "while", "conditional", "reduce", "rng",
    "all-reduce", "custom-call", "pad", "select",
)


def hlo_census(path: str) -> Counter:
    """Count instruction kinds in an HLO text file."""
    c: Counter = Counter()
    # `%x = f32[4,4]{1,0} convolution(...)` -> "convolution"
    op_re = re.compile(r"= [^(=]*?([a-z][a-z0-9-]*)\(")
    with open(path) as f:
        for line in f:
            m = op_re.search(line)
            if m:
                c[m.group(1)] += 1
            c["instructions"] += 1
    return c


def conv_layer_count(manifest, model: str) -> int:
    """Conv/dense layers per the manifest parameter table (one .w each)."""
    params = manifest["models"][model]["params"]
    return sum(1 for p in params if p["name"].endswith(".w"))


def analyze_model(art_dir: str, manifest, name: str) -> dict:
    entry = manifest["models"][name]
    report = {"model": name, "graphs": {}}
    for gname, g in entry["graphs"].items():
        census = hlo_census(os.path.join(art_dir, g["file"]))
        report["graphs"][gname] = {
            k: census.get(k, 0) for k in OPS_OF_INTEREST
        } | {"instructions": census["instructions"]}
    return report


def check_no_duplicate_compute(report, n_layers: int) -> list:
    """§Perf L2 invariant: conv+dot count in the train graph stays within
    the expected multiple of layer count (fwd + 2x bwd + weight-quant
    minmax has no matmuls; factor 4 is generous; beyond it something is
    being recomputed)."""
    problems = []
    train = report["graphs"].get("train")
    if not train:
        return problems
    heavy = train["convolution"] + train["dot"]
    if heavy > 4 * n_layers:
        problems.append(
            f"{report['model']}: {heavy} conv/dot ops for {n_layers} layers "
            f"(> 4x) — possible recomputation"
        )
    return problems


def kernel_estimates() -> dict:
    """§Perf L1: structural VMEM/MXU estimates at the deployed shapes."""
    shapes = {
        "fake_quant 32x32x3 batch32 (act site)": (32 * 32 * 32, 3),
        "fake_quant resnet stage1 fmap": (32 * 32 * 32, 8),
        "fake_quant classifier grads": (32, 128),
    }
    out = {}
    for label, shape in shapes.items():
        out[label] = {
            "vmem_bytes": fq.vmem_bytes(shape),
            "vmem_ok": fq.vmem_bytes(shape) < 16 * 2**20,
        }
    for mkn in [(32, 128, 16), (128, 128, 128), (1024, 512, 256)]:
        m, k, n = mkn
        out[f"qmatmul {m}x{k}x{n}"] = {
            "vmem_bytes": qm.vmem_bytes(),
            "mxu_utilization": round(qm.mxu_utilization_estimate(m, n, k), 4),
        }
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--artifacts", default="../artifacts")
    args = ap.parse_args()
    with open(os.path.join(args.artifacts, "manifest.json")) as f:
        manifest = json.load(f)

    all_problems = []
    print(f"{'model':16} {'graph':6} {'instr':>7} {'conv':>5} {'dot':>5} "
          f"{'while':>6} {'cond':>5} {'select':>7}")
    for name in manifest["models"]:
        rep = analyze_model(args.artifacts, manifest, name)
        for gname, c in rep["graphs"].items():
            print(f"{name:16} {gname:6} {c['instructions']:>7} "
                  f"{c['convolution']:>5} {c['dot']:>5} {c['while']:>6} "
                  f"{c['conditional']:>5} {c['select']:>7}")
        all_problems += check_no_duplicate_compute(
            rep, conv_layer_count(manifest, name))

    print("\nL1 kernel structural estimates:")
    for label, est in kernel_estimates().items():
        print(f"  {label}: {est}")

    if all_problems:
        print("\nPROBLEMS:")
        for p in all_problems:
            print(f"  {p}")
        raise SystemExit(1)
    print("\nno recomputation problems detected.")


if __name__ == "__main__":
    main()

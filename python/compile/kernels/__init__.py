"""L1 Pallas kernels (build-time only; lowered into the AOT artifacts)."""
from . import fake_quant, qmatmul, ref  # noqa: F401

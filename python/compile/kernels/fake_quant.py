"""L1 Pallas kernel: fused fake-quant + online min/max statistics.

This is the kernel-level realization of the paper's Fig. 3: the tensor is
quantized **statically** with pre-computed ranges while, in the same pass,
min/max statistics of the unquantized values are collected "at the
accumulator" — i.e. in VMEM scratch, never via a second traversal of HBM.

TPU mapping (see DESIGN.md §4.3): the grid walks row-blocks of the
flattened tensor; each block is one HBM→VMEM tile.  The statistics output
is a (1, 2) block revisited by every grid step, which on TPU lives in VMEM
for the whole kernel — the software analogue of the accumulator-side
min/max registers the paper asks the hardware for.

``interpret=True`` always: the CPU PJRT client cannot execute Mosaic
custom-calls, and interpret-mode lowers to plain HLO that the Rust runtime
runs unmodified.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default row-block: sized so a f32 block of (BLOCK_ROWS, <=1024) columns
# stays well under a 16 MiB VMEM budget together with its noise operand and
# output tile (3 live tiles * 4 B * 256 * 1024 = 3 MiB).
BLOCK_ROWS = 256

def _kernel(x_ref, range_ref, noise_ref, out_ref, stats_ref, *, bits, stochastic):
    """One grid step: quantize a row-block, fold its min/max into stats."""
    x = x_ref[...]

    qmin = jnp.minimum(range_ref[0, 0], 0.0)
    qmax = jnp.maximum(range_ref[0, 1], 0.0)
    n_levels = float((1 << bits) - 1)
    scale = jnp.maximum((qmax - qmin) / n_levels, 1e-12)
    zp = jnp.round(-qmin / scale)

    t = x / scale + zp
    if stochastic:
        t = jnp.floor(t + noise_ref[...])
    else:
        t = jnp.round(t)
    t = jnp.clip(t, 0.0, n_levels)
    out_ref[...] = (t - zp) * scale

    # Online statistics: initialized on the first grid step, folded on every
    # step.  The (1, 2) stats block maps to the same output tile for all i,
    # so the running value is carried in VMEM across steps.
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        stats_ref[0, 0] = float("inf")
        stats_ref[0, 1] = float("-inf")

    stats_ref[0, 0] = jnp.minimum(stats_ref[0, 0], jnp.min(x))
    stats_ref[0, 1] = jnp.maximum(stats_ref[0, 1], jnp.max(x))


def _pad_rows(x2, block_rows, pad_value):
    rows = x2.shape[0]
    rem = rows % block_rows
    if rem == 0:
        return x2, rows
    pad = block_rows - rem
    x2 = jnp.pad(x2, ((0, pad), (0, 0)), constant_values=pad_value)
    return x2, rows


@functools.partial(jax.jit, static_argnames=("bits", "block_rows"))
def fake_quant_with_stats(x, ranges, noise=None, *, bits: int = 8,
                          block_rows: int = BLOCK_ROWS):
    """Fused static fake-quant + pre-quant min/max stats (Pallas).

    Args:
      x:       any-shape f32 tensor.
      ranges:  shape (2,) = (qmin, qmax), the *pre-computed* quantization
               range (in-hindsight: the EMA state from previous steps).
      noise:   optional uniform-[0,1) tensor of x's shape -> stochastic
               rounding (used for gradients); None -> nearest rounding.
      bits:    grid bit-width.

    Returns ``(x_q, stats)`` — quantized tensor of x's shape and the (2,)
    min/max of the unquantized input, matching ``ref.fake_quant_with_stats``.
    """
    stochastic = noise is not None
    orig_shape = x.shape
    x2 = x.reshape(-1, orig_shape[-1]) if x.ndim > 1 else x.reshape(1, -1)
    cols = x2.shape[1]

    # Padding rows must not perturb the statistics: pad with the first
    # element so min/max are unchanged.
    pad_value = 0.0
    x2 = x2.astype(jnp.float32)
    if x2.shape[0] % block_rows != 0:
        pad_value = x2[0, 0]
    x2p, valid_rows = _pad_rows(x2, block_rows, pad_value)
    if stochastic:
        n2 = noise.reshape(x2.shape).astype(jnp.float32)
        n2p, _ = _pad_rows(n2, block_rows, 0.5)
    else:
        n2p = jnp.zeros((block_rows, cols), jnp.float32)  # dummy operand

    grid = (x2p.shape[0] // block_rows,)
    ranges2 = ranges.reshape(1, 2).astype(jnp.float32)

    kernel = functools.partial(_kernel, bits=bits, stochastic=stochastic)
    out, stats = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, cols), lambda i: (i, 0)),
            pl.BlockSpec((1, 2), lambda i: (0, 0)),
            (pl.BlockSpec((block_rows, cols), lambda i: (i, 0))
             if stochastic else pl.BlockSpec((block_rows, cols), lambda i: (0, 0))),
        ],
        out_specs=[
            pl.BlockSpec((block_rows, cols), lambda i: (i, 0)),
            pl.BlockSpec((1, 2), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(x2p.shape, jnp.float32),
            jax.ShapeDtypeStruct((1, 2), jnp.float32),
        ],
        interpret=True,
    )(x2p, ranges2, n2p)

    out = out[:valid_rows].reshape(orig_shape)
    return out, stats.reshape(2)


def vmem_bytes(shape, *, bits: int = 8, block_rows: int = BLOCK_ROWS,
               stochastic: bool = False) -> int:
    """Static VMEM footprint estimate for the kernel at a given shape.

    Used by the §Perf analysis (interpret-mode wallclock is not a TPU
    proxy; the structural budget is).  Counts the live f32 tiles: input
    block, output block, optional noise block, ranges and stats.
    """
    cols = shape[-1] if len(shape) > 1 else int(jnp.prod(jnp.array(shape)))
    tile = block_rows * cols * 4
    tiles = 2 + (1 if stochastic else 0)
    return tiles * tile + 2 * 4 + 2 * 4

"""L1 Pallas kernel: tiled matmul with quantize-at-accumulator epilogue.

The kernel-level realization of the paper's Fig. 2 (static path): the MAC
array computes the output in (bm, bn) slices accumulated over K in a f32
tile (the 32-bit accumulator).  On the *last* K step the tile is (a) folded
into the online min/max statistics and (b) statically quantized with the
pre-computed ranges before it is written back — so only low-bit-sized data
ever leaves the accumulator, which is exactly the memory-traffic argument
of eq. (4) vs eq. (5) in the paper.

TPU mapping: grid (M/bm, N/bn, K/bk); A and B tiles stream HBM→VMEM; the
accumulator tile is the revisited output block (VMEM-resident across the K
loop); the MXU consumes the (bm, bk) x (bk, bn) tiles.  interpret=True for
CPU-PJRT executability (see fake_quant.py).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes: MXU-friendly 128x128 output tiles, 128-deep K slices.
# VMEM per step: A + B + acc/out tiles = 3 * 128*128*4 B = 192 KiB « 16 MiB.
BM, BN, BK = 128, 128, 128


def _kernel(a_ref, b_ref, range_ref, out_ref, stats_ref, *, bits, n_k):
    """Grid step (i, j, k): out += A[i,k] @ B[k,j]; epilogue on last k.

    ``out_ref`` doubles as the f32 accumulator: its index map ignores k, so
    the same block stays resident across the K loop (VMEM on TPU).
    """
    i = pl.program_id(0)
    j = pl.program_id(1)
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _epilogue():
        y = out_ref[...]

        # Online accumulator statistics (Fig. 3 logic): init on the first
        # output tile, fold the tile min/max on every completed tile.
        @pl.when(jnp.logical_and(i == 0, j == 0))
        def _init():
            stats_ref[0, 0] = float("inf")
            stats_ref[0, 1] = float("-inf")

        stats_ref[0, 0] = jnp.minimum(stats_ref[0, 0], jnp.min(y))
        stats_ref[0, 1] = jnp.maximum(stats_ref[0, 1], jnp.max(y))

        # Static quantization of the accumulator tile (pre-computed range),
        # nearest rounding (activation path).
        qmin = jnp.minimum(range_ref[0, 0], 0.0)
        qmax = jnp.maximum(range_ref[0, 1], 0.0)
        n_levels = float((1 << bits) - 1)
        scale = jnp.maximum((qmax - qmin) / n_levels, 1e-12)
        zp = jnp.round(-qmin / scale)
        t = jnp.clip(jnp.round(y / scale + zp), 0.0, n_levels)
        out_ref[...] = (t - zp) * scale


def _pad_to(x, m0, m1):
    p0 = (-x.shape[0]) % m0
    p1 = (-x.shape[1]) % m1
    if p0 or p1:
        x = jnp.pad(x, ((0, p0), (0, p1)))
    return x


@functools.partial(jax.jit, static_argnames=("bits", "bm", "bn", "bk"))
def qmatmul(a, b, ranges, *, bits: int = 8, bm: int = BM, bn: int = BN,
            bk: int = BK):
    """``fake_quant(a @ b)`` with fused accumulator min/max statistics.

    Args:
      a: (M, K) f32.    b: (K, N) f32.
      ranges: (2,) pre-computed (qmin, qmax) for the output quantizer.

    Returns ``(y_q, stats)`` matching ``ref.qmatmul``.

    Shapes are zero-padded to tile multiples internally.  Padded lanes
    contribute exact zeros to the accumulator, so the statistics fold can
    only widen the observed range to include 0 — and the paper's asymmetric
    grid *always* contains 0 (``ref.quant_params`` clamps the range around
    it), so padding never changes the quantization grid.
    """
    m, kdim = a.shape
    _, n = b.shape
    bm_ = min(bm, _round_up(m, 8))
    bn_ = min(bn, _round_up(n, 8))
    bk_ = min(bk, _round_up(kdim, 8))
    ap = _pad_to(a.astype(jnp.float32), bm_, bk_)
    bp = _pad_to(b.astype(jnp.float32), bk_, bn_)
    grid = (ap.shape[0] // bm_, bp.shape[1] // bn_, ap.shape[1] // bk_)
    ranges2 = ranges.reshape(1, 2).astype(jnp.float32)

    kernel = functools.partial(_kernel, bits=bits, n_k=grid[2])
    out, stats = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm_, bk_), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk_, bn_), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, 2), lambda i, j, k: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bm_, bn_), lambda i, j, k: (i, j)),
            pl.BlockSpec((1, 2), lambda i, j, k: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((ap.shape[0], bp.shape[1]), jnp.float32),
            jax.ShapeDtypeStruct((1, 2), jnp.float32),
        ],
        interpret=True,
    )(ap, bp, ranges2)

    return out[:m, :n], stats.reshape(2)


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def mxu_utilization_estimate(m: int, n: int, k: int, *, bm: int = BM,
                             bn: int = BN, bk: int = BK) -> float:
    """Fraction of MXU-issued MACs that are useful (non-padding) work.

    §Perf structural estimate: padded tile lanes waste MXU cycles; this is
    useful_macs / issued_macs for the chosen tiling.
    """
    bm_ = min(bm, _round_up(m, 8))
    bn_ = min(bn, _round_up(n, 8))
    bk_ = min(bk, _round_up(k, 8))
    gm, gn, gk = math.ceil(m / bm_), math.ceil(n / bn_), math.ceil(k / bk_)
    issued = gm * bm_ * gn * bn_ * gk * bk_
    return (m * n * k) / issued


def vmem_bytes(*, bm: int = BM, bn: int = BN, bk: int = BK) -> int:
    """Live VMEM bytes for one grid step (A, B, acc/out tiles, f32)."""
    return 4 * (bm * bk + bk * bn + bm * bn) + 16

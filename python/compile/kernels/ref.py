"""Pure-jnp reference oracles for the Pallas kernels (L1 correctness ground truth).

Everything here mirrors the paper's quantization spec (Sec. 5.2):

* asymmetric uniform quantization on a ``2**bits - 1``-step grid,
* the grid always contains zero (required so that zero-padding / ReLU zeros
  and zero gradients are exactly representable),
* nearest rounding for weights/activations, *stochastic* rounding for
  gradients (Gupta et al. 2015), driven by externally supplied uniform noise
  so that the Pallas kernel and this oracle are bit-identical,
* ``min``/``max`` statistics of the *pre-quantization* tensor are returned
  alongside — they model the accumulator-level statistics logic of Fig. 3.

These functions are used (a) by pytest/hypothesis as the oracle for the
Pallas kernels and (b) by the L2 model as the plain-XLA fallback path.
"""

from __future__ import annotations

import jax.numpy as jnp

# Threshold below which a quantization range is considered degenerate.  A
# degenerate (all-zero) tensor quantizes to all zeros; we guard the scale so
# that no Inf/NaN can be produced on the hot path.
EPS_SCALE = 1e-12


def quant_params(qmin, qmax, bits: int):
    """Asymmetric-uniform grid parameters for range ``[qmin, qmax]``.

    Returns ``(scale, zero_point, n_levels)`` where the integer grid is
    ``{0, ..., n_levels}`` and ``dequant(v) = (v - zero_point) * scale``.
    The range is first widened to contain 0 (paper Sec. 5.2 / standard
    asymmetric quantization), and the zero-point is rounded to an integer so
    that 0.0 is exactly representable.
    """
    qmin = jnp.minimum(jnp.asarray(qmin, jnp.float32), 0.0)
    qmax = jnp.maximum(jnp.asarray(qmax, jnp.float32), 0.0)
    n_levels = (1 << bits) - 1
    scale = (qmax - qmin) / n_levels
    scale = jnp.maximum(scale, EPS_SCALE)
    zero_point = jnp.round(-qmin / scale)
    return scale, zero_point, n_levels


def fake_quant(x, qmin, qmax, bits: int = 8, noise=None):
    """Simulated (fake) asymmetric uniform quantization of ``x``.

    ``noise`` — if given, uniform-[0,1) tensor of ``x``'s shape enabling
    stochastic rounding (``floor(t + u)``); otherwise round-to-nearest.
    Values outside ``[qmin, qmax]`` saturate to the grid edges.
    """
    scale, zp, n = quant_params(qmin, qmax, bits)
    t = x / scale + zp
    if noise is None:
        t = jnp.round(t)
    else:
        t = jnp.floor(t + noise)
    t = jnp.clip(t, 0.0, float(n))
    return (t - zp) * scale


def minmax(x):
    """Per-tensor (min, max) — the accumulator statistics of Fig. 3."""
    return jnp.stack([jnp.min(x), jnp.max(x)])


def fake_quant_with_stats(x, ranges, bits: int = 8, noise=None):
    """Fused fake-quant + pre-quant min/max stats (oracle for the L1 kernel).

    ``ranges`` — shape ``(2,)`` = (qmin, qmax) used for quantization.
    Returns ``(x_q, stats)`` with ``stats`` shape ``(2,)`` holding the
    min/max of the *input* tensor (not of the quantized output).
    """
    xq = fake_quant(x, ranges[0], ranges[1], bits=bits, noise=noise)
    return xq, minmax(x)


def qmatmul(a, b, ranges, bits: int = 8, noise=None):
    """Oracle for the quantize-at-accumulator matmul kernel.

    Computes ``y = a @ b`` in f32 (the 32-bit accumulator), collects
    min/max of ``y`` (accumulator statistics), and emits the statically
    quantized output — the static-quantization dataflow of Fig. 2 (left).
    Returns ``(y_q, stats)``.
    """
    y = jnp.matmul(a, b, preferred_element_type=jnp.float32)
    return fake_quant_with_stats(y, ranges, bits=bits, noise=noise)


def saturation_ratio(x, qmin, qmax):
    """Fraction of elements outside the quantization grid (paper footnote 1)."""
    out = jnp.logical_or(x < qmin, x > qmax)
    return jnp.mean(out.astype(jnp.float32))


def ema_update(prev_ranges, stats, eta):
    """In-hindsight / running min-max EMA (paper eqs. 2-3).

    ``new = (1 - eta) * stats + eta * prev``, per component.
    """
    return (1.0 - eta) * stats + eta * prev_ranges

"""L2 training/eval/init graph builders with a flat, manifest-friendly ABI.

Everything the Rust coordinator varies at runtime is a graph *input*
(estimator modes, enables, ranges, eta, lr, weight decay, seed); everything
per-step state is a graph *output* (params, momentum, BN state, range
state, accumulator statistics).  Python is never on the step path.

Graph ABIs (flat argument order == manifest order):

  init (seed:i32)
      -> params..., opt..., state...

  train (params..., opt..., state..., x, y:i32,
         ranges[Q,2], mode_act, mode_grad, wq_on, aq_on, gq_on,
         eta, lr, wd, seed:i32)
      -> new_params..., new_opt..., new_state...,
         loss, acc, new_ranges[Q,2], stats[Q,2]

  eval (params..., state..., x, y:i32, ranges[Q,2], mode_act, wq_on, aq_on)
      -> loss_sum, correct_count

  dump (params..., state..., x, y:i32, ranges[Q,2], mode_grad, wq_on,
        aq_on, gq_on, eta, seed:i32)
      -> grads per grad-site (raw FP G_X tensors, DSGC's expensive readback)

The optimizer is SGD with momentum 0.9 and coupled weight decay, matching
the paper's setup; the weight update itself stays FP32 (Sec. 3.1).
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from . import nn, quant_ops as qo

MOMENTUM = 0.9


def _xent(logits, y):
    logp = jax.nn.log_softmax(logits)
    return -jnp.take_along_axis(logp, y[:, None], axis=1).mean()


def _make_ctx(model, ranges, mode_act, mode_grad, wq_on, aq_on, gq_on,
              eta, seed, cfg, tap):
    key = jax.random.PRNGKey(seed)
    return qo.QuantCtx(ranges=ranges, mode_act=mode_act, mode_grad=mode_grad,
                       wq_on=wq_on, aq_on=aq_on, gq_on=gq_on, eta=eta,
                       key=key, cfg=cfg, tap=tap)


def _grad_sites(model):
    return [s for s in model.reg.sites if s.kind == "grad"]


def _assemble_site_outputs(model, collect: nn.Collector, dummy_grads):
    """Merge fwd (act) and bwd (grad) site stats into global (Q,2) arrays."""
    stats, new_ranges = [], []
    for s in model.reg.sites:
        if s.kind == "act":
            stats.append(collect.stats[s.index])
            new_ranges.append(collect.new_ranges[s.index])
        else:
            packed = dummy_grads[s.index]       # (2,2): [stats; new_range]
            stats.append(packed[0])
            new_ranges.append(packed[1])
    return jnp.stack(stats), jnp.stack(new_ranges)


def make_train_step(model: nn.Model, batch_size: int, cfg: qo.QuantConfig):
    """Returns (fn, example_args) for the train graph."""
    P, S = len(model.reg.params), len(model.reg.state)
    Q = len(model.reg.sites)

    def fn(*flat):
        pv = list(flat[:P])
        ov = list(flat[P:2 * P])
        sv = list(flat[2 * P:2 * P + S])
        (x, y, ranges, mode_act, mode_grad, wq_on, aq_on, gq_on, eta, lr,
         wd, seed) = flat[2 * P + S:]

        ctx = _make_ctx(model, ranges, mode_act, mode_grad, wq_on, aq_on,
                        gq_on, eta, seed, cfg, qo.grad_tap)
        dummies = {s.index: jnp.zeros((2, 2), jnp.float32)
                   for s in _grad_sites(model)}

        def loss_fn(pv, dummies):
            collect = nn.Collector(Q)
            logits, new_sv = model.apply(pv, sv, x, ctx, True, dummies,
                                         collect)
            loss = _xent(logits, y)
            return loss, (logits, new_sv, collect)

        (loss, (logits, new_sv, collect)), (grads, dgrads) = (
            jax.value_and_grad(loss_fn, argnums=(0, 1), has_aux=True)(
                pv, dummies))

        acc = jnp.mean((jnp.argmax(logits, axis=1) == y).astype(jnp.float32))
        stats, new_ranges = _assemble_site_outputs(model, collect, dgrads)

        # SGD + momentum, coupled weight decay, FP32 update (paper Sec. 3.1)
        new_pv, new_ov = [], []
        for p, o, g in zip(pv, ov, grads):
            g = g + wd * p
            buf = MOMENTUM * o + g
            new_pv.append(p - lr * buf)
            new_ov.append(buf)

        return tuple(new_pv) + tuple(new_ov) + tuple(new_sv) + (
            loss, acc, new_ranges, stats)

    example = _example_params(model) * 2 + _example_state(model) + (
        jnp.zeros((batch_size, *model.input_shape), jnp.float32),
        jnp.zeros((batch_size,), jnp.int32),
        jnp.zeros((Q, 2), jnp.float32),
        jnp.float32(0), jnp.float32(0), jnp.float32(0), jnp.float32(0),
        jnp.float32(0), jnp.float32(0.9), jnp.float32(0.1), jnp.float32(0),
        jnp.int32(0),
    )
    return fn, example


def make_eval_step(model: nn.Model, batch_size: int, cfg: qo.QuantConfig):
    P, S = len(model.reg.params), len(model.reg.state)
    Q = len(model.reg.sites)

    def fn(*flat):
        pv = list(flat[:P])
        sv = list(flat[P:P + S])
        x, y, ranges, mode_act, wq_on, aq_on = flat[P + S:]
        ctx = _make_ctx(model, ranges, mode_act, jnp.float32(0), wq_on,
                        aq_on, jnp.float32(0), jnp.float32(0.9), 0, cfg,
                        qo.grad_tap)
        collect = nn.Collector(Q)
        logits, _ = model.apply(pv, sv, x, ctx, False, {}, collect)
        loss_sum = _xent(logits, y) * x.shape[0]
        correct = jnp.sum((jnp.argmax(logits, axis=1) == y).astype(jnp.float32))
        return loss_sum, correct

    example = _example_params(model) + _example_state(model) + (
        jnp.zeros((batch_size, *model.input_shape), jnp.float32),
        jnp.zeros((batch_size,), jnp.int32),
        jnp.zeros((Q, 2), jnp.float32),
        jnp.float32(2), jnp.float32(0), jnp.float32(0),
    )
    return fn, example


def make_dump_step(model: nn.Model, batch_size: int, cfg: qo.QuantConfig):
    """DSGC support graph: returns the raw FP gradient tensor per grad site
    (ordered by site index).  Deliberately expensive — this is the
    full-tensor memory readback the paper's Sec. 6 accounting charges
    dynamic quantization for."""
    P, S = len(model.reg.params), len(model.reg.state)
    Q = len(model.reg.sites)
    gsites = _grad_sites(model)

    def fn(*flat):
        pv = list(flat[:P])
        sv = list(flat[P:P + S])
        (x, y, ranges, mode_grad, wq_on, aq_on, gq_on, eta, seed) = (
            flat[P + S:])
        ctx = _make_ctx(model, ranges, jnp.float32(qo.MODE_HINDSIGHT),
                        mode_grad, wq_on, aq_on, gq_on, eta, seed, cfg,
                        qo.dump_tap)
        dummies = {s.index: jnp.zeros((batch_size, *s.feature_shape),
                                      jnp.float32) for s in gsites}

        def loss_fn(dummies):
            collect = nn.Collector(Q)
            logits, _ = model.apply(pv, sv, x, ctx, True, dummies, collect)
            return _xent(logits, y)

        dgrads = jax.grad(loss_fn)(dummies)
        return tuple(dgrads[s.index] for s in gsites)

    example = _example_params(model) + _example_state(model) + (
        jnp.zeros((batch_size, *model.input_shape), jnp.float32),
        jnp.zeros((batch_size,), jnp.int32),
        jnp.zeros((Q, 2), jnp.float32),
        jnp.float32(2), jnp.float32(0), jnp.float32(0), jnp.float32(0),
        jnp.float32(0.9), jnp.int32(0),
    )
    return fn, example


def make_init(model: nn.Model):
    """Init graph: seed -> params, opt(zeros), state."""
    def fn(seed):
        key = jax.random.PRNGKey(seed)
        pv, sv = nn.init_params(model, key)
        ov = [jnp.zeros_like(p) for p in pv]
        return tuple(pv) + tuple(ov) + tuple(sv)
    return fn, (jnp.int32(0),)


def _example_params(model) -> Tuple:
    return tuple(jnp.zeros(p.shape, jnp.float32) for p in model.reg.params)


def _example_state(model) -> Tuple:
    return tuple(jnp.zeros(p.shape, jnp.float32) for p in model.reg.state)

"""L2 model zoo: the paper's three architecture families, width-reduced.

Paper (Sec. 5): modified ResNet18, VGG16 and MobileNetV2 on Tiny ImageNet.
We keep each family's structural signature — basic residual blocks for
ResNet, plain conv stacks for VGG, inverted residuals with depthwise +
pointwise convs for MobileNetV2 (whose 1x1 convs are the 8x worst case of
Table 5) — at widths sized for CPU-PJRT training (see DESIGN.md §3).

Quantizer placement (Fig. 1): an activation quantizer after each
conv→BN→act chain (the feature map written to memory), a gradient
quantizer on each conv/dense *input* (the G_X it propagates backwards).
The first layer has no gradient site (no preceding layer to propagate to);
all layers are otherwise quantized, including first and last (Sec. 5.2).
"""

from __future__ import annotations

from . import nn


def build_mlp(n_classes: int = 10, hw: int = 8, cin: int = 3) -> nn.Model:
    """Small MLP used by unit/integration tests and the quickstart."""
    reg = nn.Registry()
    d_in = hw * hw * cin
    layers = [
        nn.flatten(),
        nn.dense(reg, "fc1", d_in, 64, grad_site=False),
        nn.relu(),
        nn.act_quant(reg, "fc1", (64,)),
        nn.dense(reg, "fc2", 64, n_classes),
    ]
    top = nn.sequential(layers)
    return nn.finalize("mlp", reg, top, (hw, hw, cin), n_classes)


def build_cnn(n_classes: int = 16, hw: int = 32) -> nn.Model:
    """Two-conv CNN (quickstart-scale)."""
    reg = nn.Registry()
    layers = [
        nn.conv2d(reg, "conv1", 3, 16, 3, grad_site=False,
                  feature_hw=(hw, hw)),
        nn.batchnorm(reg, "bn1", 16),
        nn.relu(),
        nn.act_quant(reg, "conv1", (hw, hw, 16)),
        nn.maxpool(),
        nn.conv2d(reg, "conv2", 16, 32, 3, feature_hw=(hw // 2, hw // 2)),
        nn.batchnorm(reg, "bn2", 32),
        nn.relu(),
        nn.act_quant(reg, "conv2", (hw // 2, hw // 2, 32)),
        nn.maxpool(),
        nn.flatten(),
        nn.dense(reg, "fc", (hw // 4) * (hw // 4) * 32, n_classes),
    ]
    top = nn.sequential(layers)
    return nn.finalize("cnn", reg, top, (hw, hw, 3), n_classes)


def _basic_block(reg, name, cin, cout, stride, hw_in):
    """ResNet basic block: conv-BN-ReLU-AQ-conv-BN (+shortcut) -ReLU-AQ."""
    hw_out = hw_in // stride
    branch = nn.sequential([
        nn.conv2d(reg, f"{name}.conv1", cin, cout, 3, stride=stride,
                  feature_hw=(hw_in, hw_in)),
        nn.batchnorm(reg, f"{name}.bn1", cout),
        nn.relu(),
        nn.act_quant(reg, f"{name}.conv1", (hw_out, hw_out, cout)),
        nn.conv2d(reg, f"{name}.conv2", cout, cout, 3,
                  feature_hw=(hw_out, hw_out)),
        nn.batchnorm(reg, f"{name}.bn2", cout),
    ])
    shortcut = None
    if stride != 1 or cin != cout:
        shortcut = nn.sequential([
            nn.conv2d(reg, f"{name}.down", cin, cout, 1, stride=stride,
                      feature_hw=(hw_in, hw_in)),
            nn.batchnorm(reg, f"{name}.bn_down", cout),
        ])
    return nn.sequential([
        nn.residual(branch, shortcut),
        nn.relu(),
        nn.act_quant(reg, f"{name}.out", (hw_out, hw_out, cout)),
    ]), hw_out


def build_resnet_tiny(n_classes: int = 16, hw: int = 32,
                      widths=(16, 32, 64, 128),
                      blocks=(2, 2, 2, 2)) -> nn.Model:
    """Modified-ResNet18 family member: 3x3 stem (no maxpool, per the Tiny
    ImageNet modification the paper cites), 4 stages of basic blocks.

    ``blocks`` counts basic blocks per stage — (2,2,2,2) is the ResNet18
    layout; the shipped artifacts use (1,1,1,1) (a ResNet-10 layout)
    because the runtime's XLA 0.5.1 compile time is superlinear in conv
    count (388s for the 18-layer train graph vs ~60s for 10 layers); see
    DESIGN.md §3."""
    reg = nn.Registry()
    layers = [
        nn.conv2d(reg, "stem", 3, widths[0], 3, grad_site=False,
                  feature_hw=(hw, hw)),
        nn.batchnorm(reg, "bn_stem", widths[0]),
        nn.relu(),
        nn.act_quant(reg, "stem", (hw, hw, widths[0])),
    ]
    cin, cur = widths[0], hw
    for si, c in enumerate(widths):
        for bi in range(blocks[si]):
            stride = 2 if (si > 0 and bi == 0) else 1
            block, cur = _basic_block(reg, f"s{si}b{bi}", cin, c, stride, cur)
            layers.append(block)
            cin = c
    layers += [
        nn.avgpool_global(),
        nn.dense(reg, "fc", widths[-1], n_classes),
    ]
    top = nn.sequential(layers)
    return nn.finalize("resnet_tiny", reg, top, (hw, hw, 3), n_classes)


def build_vgg_tiny(n_classes: int = 16, hw: int = 32,
                   plan=((16, 16), (32, 32), (64, 64))) -> nn.Model:
    """VGG16 family member: plain conv stacks + maxpool + FC head."""
    reg = nn.Registry()
    layers = []
    cin, cur = 3, hw
    first = True
    for gi, group in enumerate(plan):
        for ci, c in enumerate(group):
            name = f"g{gi}c{ci}"
            layers += [
                nn.conv2d(reg, name, cin, c, 3, grad_site=not first,
                          feature_hw=(cur, cur)),
                nn.batchnorm(reg, f"bn_{name}", c),
                nn.relu(),
                nn.act_quant(reg, name, (cur, cur, c)),
            ]
            cin = c
            first = False
        layers.append(nn.maxpool())
        cur //= 2
    layers += [
        nn.flatten(),
        nn.dense(reg, "fc1", cur * cur * cin, 128),
        nn.relu(),
        nn.act_quant(reg, "fc1", (128,)),
        nn.dense(reg, "fc2", 128, n_classes),
    ]
    top = nn.sequential(layers)
    return nn.finalize("vgg_tiny", reg, top, (hw, hw, 3), n_classes)


def _inverted_residual(reg, name, cin, cout, stride, expand, hw_in):
    """MobileNetV2 block: 1x1 expand → 3x3 depthwise → 1x1 project."""
    mid = cin * expand
    hw_out = hw_in // stride
    layers = []
    if expand != 1:
        layers += [
            nn.conv2d(reg, f"{name}.expand", cin, mid, 1, use_bias=False,
                      feature_hw=(hw_in, hw_in)),
            nn.batchnorm(reg, f"{name}.bn_e", mid),
            nn.relu6(),
            nn.act_quant(reg, f"{name}.expand", (hw_in, hw_in, mid)),
        ]
    layers += [
        nn.conv2d(reg, f"{name}.dw", mid, mid, 3, stride=stride,
                  depthwise=True, use_bias=False, feature_hw=(hw_in, hw_in)),
        nn.batchnorm(reg, f"{name}.bn_d", mid),
        nn.relu6(),
        nn.act_quant(reg, f"{name}.dw", (hw_out, hw_out, mid)),
        nn.conv2d(reg, f"{name}.project", mid, cout, 1, use_bias=False,
                  feature_hw=(hw_out, hw_out)),
        nn.batchnorm(reg, f"{name}.bn_p", cout),
        # linear bottleneck: quantize the projection output (no ReLU)
        nn.act_quant(reg, f"{name}.project", (hw_out, hw_out, cout)),
    ]
    branch = nn.sequential(layers)
    if stride == 1 and cin == cout:
        return nn.residual(branch, None), hw_out
    return branch, hw_out


def build_mobilenet_tiny(n_classes: int = 16, hw: int = 32) -> nn.Model:
    """MobileNetV2 family member: inverted residuals, ReLU6, linear
    bottlenecks; includes the pointwise-conv shapes Table 5 highlights."""
    reg = nn.Registry()
    layers = [
        nn.conv2d(reg, "stem", 3, 16, 3, grad_site=False,
                  feature_hw=(hw, hw)),
        nn.batchnorm(reg, "bn_stem", 16),
        nn.relu6(),
        nn.act_quant(reg, "stem", (hw, hw, 16)),
    ]
    plan = [  # (expand, cout, stride) — compile-budget-reduced block count
        (1, 16, 1), (4, 24, 2), (4, 32, 2), (4, 64, 2),
    ]
    cin, cur = 16, hw
    for i, (t, c, s) in enumerate(plan):
        block, cur = _inverted_residual(reg, f"b{i}", cin, c, s, t, cur)
        layers.append(block)
        cin = c
    layers += [
        nn.conv2d(reg, "head", cin, 128, 1, feature_hw=(cur, cur)),
        nn.batchnorm(reg, "bn_head", 128),
        nn.relu6(),
        nn.act_quant(reg, "head", (cur, cur, 128)),
        nn.avgpool_global(),
        nn.dense(reg, "fc", 128, n_classes),
    ]
    top = nn.sequential(layers)
    return nn.finalize("mobilenet_tiny", reg, top, (hw, hw, 3), n_classes)


BUILDERS = {
    "mlp": build_mlp,
    "cnn": build_cnn,
    "resnet_tiny": build_resnet_tiny,
    "vgg_tiny": build_vgg_tiny,
    "mobilenet_tiny": build_mobilenet_tiny,
}


def build(name: str, **kw) -> nn.Model:
    return BUILDERS[name](**kw)

"""L2 model/train-graph tests: shapes, ABI arity, training dynamics and
estimator-mode equivalences at the whole-graph level."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import models, train, quant_ops as qo

CFG = qo.QuantConfig(use_pallas="none")


def build_args(model, fn_ex, bs, *, mode=2.0, enables=(1.0, 1.0, 1.0),
               lr=0.1, seed=0, ranges_val=1.0):
    fn, ex = fn_ex
    P, S, Q = len(model.reg.params), len(model.reg.state), len(model.reg.sites)
    init_fn, _ = train.make_init(model)
    carry = jax.jit(init_fn)(jnp.int32(seed))
    x = jax.random.normal(jax.random.PRNGKey(seed + 1),
                          (bs, *model.input_shape))
    y = jax.random.randint(jax.random.PRNGKey(seed + 2), (bs,), 0,
                           model.n_classes).astype(jnp.int32)
    ranges = jnp.tile(jnp.array([[-ranges_val, ranges_val]]), (Q, 1))
    wq, aq, gq = enables
    args = tuple(carry) + (x, y, ranges,
                           jnp.float32(mode), jnp.float32(mode),
                           jnp.float32(wq), jnp.float32(aq), jnp.float32(gq),
                           jnp.float32(0.9), jnp.float32(lr),
                           jnp.float32(1e-4), jnp.int32(seed))
    return fn, args, (P, S, Q)


@pytest.mark.parametrize("name,kw,bs", [
    ("mlp", dict(), 4),
    ("cnn", dict(hw=16), 4),
    ("resnet_tiny", dict(hw=16, widths=(4, 8, 8, 8)), 2),
    ("vgg_tiny", dict(hw=16, plan=((4,), (8,))), 2),
    ("mobilenet_tiny", dict(hw=16), 2),
])
def test_all_models_train_step_shapes(name, kw, bs):
    model = models.build(name, **kw)
    fn_ex = train.make_train_step(model, bs, CFG)
    fn, args, (P, S, Q) = build_args(model, fn_ex, bs)
    out = jax.jit(fn)(*args)
    assert len(out) == 2 * P + S + 4
    loss, acc = out[2 * P + S], out[2 * P + S + 1]
    assert jnp.isfinite(loss) and 0.0 <= float(acc) <= 1.0
    new_ranges, stats = out[2 * P + S + 2], out[2 * P + S + 3]
    assert new_ranges.shape == (Q, 2) and stats.shape == (Q, 2)
    # stats rows are ordered (min <= max)
    assert bool(jnp.all(stats[:, 0] <= stats[:, 1] + 1e-6))


def test_param_count_bookkeeping():
    model = models.build("resnet_tiny", hw=32, widths=(8, 16, 32, 64))
    total = sum(int(np.prod(p.shape)) for p in model.reg.params)
    assert model.n_params == total
    # 4 stages x 2 blocks + stem + fc and BN params all registered
    assert len(model.reg.params) > 40
    # every grad site has a matching param layer upstream
    assert len([s for s in model.reg.sites if s.kind == "grad"]) >= 17


def test_training_reduces_loss_mlp():
    model = models.build("mlp")
    fn_ex = train.make_train_step(model, 8, CFG)
    fn, args, (P, S, Q) = build_args(model, fn_ex, 8, lr=0.2)
    jfn = jax.jit(fn)
    args = list(args)
    first = last = None
    for step in range(40):
        out = jfn(*args)
        loss = float(out[2 * P + S])
        first = loss if first is None else first
        last = loss
        # thread state + ranges
        args[:2 * P + S] = out[:2 * P + S]
        args[2 * P + S + 2] = out[2 * P + S + 2]
    assert last < first * 0.7, f"{first} -> {last}"


def test_quant_disabled_equals_across_modes():
    """With all enables off the estimator mode must not affect the step."""
    model = models.build("mlp")
    fn_ex = train.make_train_step(model, 4, CFG)
    outs = []
    for mode in (0.0, 1.0, 2.0):
        fn, args, (P, S, Q) = build_args(model, fn_ex, 4,
                                         mode=mode, enables=(0, 0, 0))
        outs.append(jax.jit(fn)(*args))
    # compare params/opt/state/loss/acc and stats; `new_ranges` (index
    # 2P+S+2) legitimately differs across modes — its state-update rule is
    # mode-dependent even when quantization is disabled.
    model0 = models.build("mlp")
    P, S = len(model0.reg.params), len(model0.reg.state)
    skip = 2 * P + S + 2
    for other in (outs[1], outs[2]):
        for i, (a, b) in enumerate(zip(outs[0], other)):
            if i == skip:
                continue
            np.testing.assert_allclose(a, b, atol=0)


def test_quant_enabled_changes_the_math():
    model = models.build("mlp")
    fn_ex = train.make_train_step(model, 4, CFG)
    fn, args_on, (P, S, _) = build_args(model, fn_ex, 4, enables=(1, 1, 1))
    _, args_off, _ = build_args(model, fn_ex, 4, enables=(0, 0, 0))
    on = jax.jit(fn)(*args_on)
    off = jax.jit(fn)(*args_off)
    diffs = sum(
        float(jnp.abs(a - b).max()) for a, b in zip(on[:P], off[:P]))
    assert diffs > 0.0, "quantization had no effect on the update"


def test_hindsight_mode_ranges_follow_eqs23():
    model = models.build("mlp")
    fn_ex = train.make_train_step(model, 4, CFG)
    fn, args, (P, S, Q) = build_args(model, fn_ex, 4, mode=2.0)
    out = jax.jit(fn)(*args)
    new_ranges = np.asarray(out[2 * P + S + 2])
    stats = np.asarray(out[2 * P + S + 3])
    prev = np.tile([[-1.0, 1.0]], (Q, 1)).astype(np.float32)
    np.testing.assert_allclose(new_ranges, 0.1 * stats + 0.9 * prev,
                               rtol=1e-4, atol=1e-5)


def test_eval_graph_counts_correct():
    model = models.build("mlp")
    bs = 8
    fn, ex = train.make_eval_step(model, bs, CFG)
    init_fn, _ = train.make_init(model)
    carry = jax.jit(init_fn)(jnp.int32(0))
    P, S, Q = len(model.reg.params), len(model.reg.state), len(model.reg.sites)
    x = jax.random.normal(jax.random.PRNGKey(5), (bs, *model.input_shape))
    y = jnp.zeros((bs,), jnp.int32)
    ranges = jnp.tile(jnp.array([[-1.0, 1.0]]), (Q, 1))
    loss_sum, correct = jax.jit(fn)(
        *carry[:P], *carry[2 * P:], x, y, ranges,
        jnp.float32(2), jnp.float32(0), jnp.float32(0))
    assert float(loss_sum) > 0.0
    assert 0 <= float(correct) <= bs


def test_dump_graph_returns_grad_tensors():
    model = models.build("mlp")
    bs = 4
    fn, ex = train.make_dump_step(model, bs, CFG)
    init_fn, _ = train.make_init(model)
    carry = jax.jit(init_fn)(jnp.int32(0))
    P = len(model.reg.params)
    gsites = [s for s in model.reg.sites if s.kind == "grad"]
    x = jax.random.normal(jax.random.PRNGKey(6), (bs, *model.input_shape))
    y = jnp.zeros((bs,), jnp.int32)
    Q = len(model.reg.sites)
    ranges = jnp.tile(jnp.array([[-1.0, 1.0]]), (Q, 1))
    outs = jax.jit(fn)(*carry[:P], x, y, ranges, jnp.float32(2),
                       jnp.float32(1), jnp.float32(1), jnp.float32(1),
                       jnp.float32(0.9), jnp.int32(0))
    assert len(outs) == len(gsites)
    for g, site in zip(outs, gsites):
        assert g.shape == (bs, *site.feature_shape)
        assert bool(jnp.any(g != 0.0)), "gradient tensor is all zeros"


def test_batchnorm_state_updates_in_train_only():
    model = models.build("cnn", hw=16)
    fn_ex = train.make_train_step(model, 4, CFG)
    fn, args, (P, S, Q) = build_args(model, fn_ex, 4)
    out = jax.jit(fn)(*args)
    state_in = args[2 * P:2 * P + S]
    state_out = out[2 * P:2 * P + S]
    moved = sum(float(jnp.abs(a - b).max()) for a, b in zip(state_in, state_out))
    assert moved > 0.0, "BN running stats did not update during training"


def test_stochastic_rounding_seed_sensitivity():
    """Different seeds give different quantized-gradient trajectories."""
    model = models.build("mlp")
    fn_ex = train.make_train_step(model, 4, CFG)
    fn, args1, (P, S, _) = build_args(model, fn_ex, 4)
    args2 = list(args1)
    args2[-1] = jnp.int32(99)  # different stochastic-rounding seed
    o1 = jax.jit(fn)(*args1)
    o2 = jax.jit(fn)(*args2)
    diff = sum(float(jnp.abs(a - b).max()) for a, b in zip(o1[:P], o2[:P]))
    assert diff > 0.0

"""L2 quantization-op semantics: estimator mode switching, STE, gradient
taps, and the dummy-cotangent statistics channel."""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import quant_ops as qo
from compile.kernels import ref

CFG = qo.QuantConfig(use_pallas="none")
CFG_PALLAS = qo.QuantConfig(use_pallas="all")


def make_ctx(ranges, mode_act=2, mode_grad=2, aq=1.0, gq=1.0, wq=1.0,
             eta=0.9, seed=0, cfg=CFG):
    return qo.QuantCtx(
        ranges=jnp.asarray(ranges, jnp.float32),
        mode_act=jnp.float32(mode_act),
        mode_grad=jnp.float32(mode_grad),
        wq_on=jnp.float32(wq),
        aq_on=jnp.float32(aq),
        gq_on=jnp.float32(gq),
        eta=jnp.float32(eta),
        key=jax.random.PRNGKey(seed),
        cfg=cfg,
        tap=qo.grad_tap,
    )


# ---------------------------------------------------------------------------
# act_quant: mode semantics
# ---------------------------------------------------------------------------

def test_act_quant_hindsight_uses_prev_ranges():
    """Static mode quantizes with the *input* ranges: values beyond them
    saturate even though current stats are wider."""
    x = jnp.array([[-5.0, 0.0, 5.0]])
    ctx = make_ctx([[-1.0, 1.0]], mode_act=qo.MODE_HINDSIGHT)
    y, stats, new_range = qo.act_quant(x, 0, ctx)
    assert float(y.max()) <= 1.01  # saturated at the stale range
    np.testing.assert_allclose(stats, [-5.0, 5.0])  # stats see the truth
    # eqs. 2-3: new = 0.1 * stats + 0.9 * prev
    np.testing.assert_allclose(new_range, [0.1 * -5.0 + 0.9 * -1.0,
                                           0.1 * 5.0 + 0.9 * 1.0], rtol=1e-5)


def test_act_quant_current_uses_current_stats():
    x = jnp.array([[-5.0, 0.0, 5.0]])
    ctx = make_ctx([[-1.0, 1.0]], mode_act=qo.MODE_CURRENT)
    y, _, new_range = qo.act_quant(x, 0, ctx)
    assert float(jnp.abs(y - x).max()) < 0.05  # no saturation
    np.testing.assert_allclose(new_range, [-5.0, 5.0])


def test_act_quant_running_blends_before_quantizing():
    x = jnp.array([[-5.0, 0.0, 5.0]])
    ctx = make_ctx([[-1.0, 1.0]], mode_act=qo.MODE_RUNNING, eta=0.5)
    y, _, new_range = qo.act_quant(x, 0, ctx)
    # blended range = 0.5*stats + 0.5*prev = [-3, 3]: mild saturation
    assert 2.9 <= float(y.max()) <= 3.05
    np.testing.assert_allclose(new_range, [-3.0, 3.0], rtol=1e-6)


def test_act_quant_disabled_is_identity():
    x = jnp.array([[-5.0, 0.2, 5.0]])
    ctx = make_ctx([[-1.0, 1.0]], aq=0.0)
    y, _, _ = qo.act_quant(x, 0, ctx)
    np.testing.assert_allclose(y, x)


def test_act_quant_straight_through_gradient():
    def f(x):
        ctx = make_ctx([[-1.0, 1.0]])
        y, _, _ = qo.act_quant(x, 0, ctx)
        return jnp.sum(y * 3.0)

    g = jax.grad(f)(jnp.ones((2, 2)) * 0.3)
    np.testing.assert_allclose(g, 3.0 * jnp.ones((2, 2)))  # STE: identity


# ---------------------------------------------------------------------------
# weight_quant
# ---------------------------------------------------------------------------

def test_weight_quant_current_minmax_ste():
    w = jnp.array([-0.31, 0.17, 0.49])
    ctx = make_ctx([[0.0, 0.0]])
    wq = qo.weight_quant(w, ctx)
    wq_ref = ref.fake_quant(w, w.min(), w.max(), bits=8)
    np.testing.assert_allclose(wq, wq_ref, atol=1e-6)
    g = jax.grad(lambda w: jnp.sum(qo.weight_quant(w, ctx)))(w)
    np.testing.assert_allclose(g, jnp.ones(3))


def test_weight_quant_gated_off():
    w = jnp.array([-0.31, 0.17, 0.49])
    ctx = make_ctx([[0.0, 0.0]], wq=0.0)
    np.testing.assert_allclose(qo.weight_quant(w, ctx), w)


# ---------------------------------------------------------------------------
# grad_tap: backward quantization + dummy-cotangent stats channel
# ---------------------------------------------------------------------------

def tap_loss(site, ctx):
    """loss = 0.5*sum(tap(x)^2) so dL/dx (pre-tap) = quantize(x)."""
    def f(x, dummy):
        y = qo.grad_tap(x, dummy, site, ctx)
        return 0.5 * jnp.sum(y * y)
    return f


def test_grad_tap_quantizes_cotangent():
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 8)) * 2
    ctx = make_ctx([[-3.0, 3.0]], mode_grad=qo.MODE_HINDSIGHT, seed=5)
    dummy = jnp.zeros((2, 2))
    gx, gd = jax.grad(tap_loss(0, ctx), argnums=(0, 1))(x, dummy)
    # cotangent is x itself quantized stochastically on the [-3,3] grid
    noise = jax.random.uniform(jax.random.fold_in(ctx.key, 0), x.shape)
    gx_ref = ref.fake_quant(x, jnp.float32(-3.0), jnp.float32(3.0), bits=8,
                            noise=noise)
    np.testing.assert_allclose(gx, gx_ref, atol=1e-5)
    # dummy cotangent row 0 = stats (minmax of raw gradient = x)
    np.testing.assert_allclose(gd[0], [x.min(), x.max()], rtol=1e-6)
    # row 1 = EMA state update
    np.testing.assert_allclose(
        gd[1],
        ref.ema_update(jnp.array([-3.0, 3.0]), gd[0], 0.9),
        rtol=1e-5,
    )


def test_grad_tap_mode_current_no_saturation():
    x = jax.random.normal(jax.random.PRNGKey(2), (16,)) * 10
    ctx = make_ctx([[-0.1, 0.1]], mode_grad=qo.MODE_CURRENT)
    gx, _ = jax.grad(tap_loss(0, ctx), argnums=(0, 1))(x, jnp.zeros((2, 2)))
    # current mode re-ranges: max error is one step of the wide grid
    step = (float(x.max()) - min(float(x.min()), 0.0)) / 255
    assert float(jnp.abs(gx - x).max()) <= step * 1.1 + 1e-5


def test_grad_tap_mode_hindsight_saturates_on_stale_range():
    x = jnp.array([10.0, -10.0, 0.5])
    ctx = make_ctx([[-1.0, 1.0]], mode_grad=qo.MODE_HINDSIGHT)
    gx, _ = jax.grad(tap_loss(0, ctx), argnums=(0, 1))(x, jnp.zeros((2, 2)))
    assert float(jnp.abs(gx).max()) <= 1.01


def test_grad_tap_disabled_passes_raw_gradient():
    x = jnp.array([10.0, -10.0, 0.5])
    ctx = make_ctx([[-1.0, 1.0]], gq=0.0)
    gx, _ = jax.grad(tap_loss(0, ctx), argnums=(0, 1))(x, jnp.zeros((2, 2)))
    np.testing.assert_allclose(gx, x)


def test_grad_tap_forward_is_identity():
    x = jnp.arange(6.0).reshape(2, 3)
    ctx = make_ctx([[-1.0, 1.0]])
    y = qo.grad_tap(x, jnp.zeros((2, 2)), 0, ctx)
    np.testing.assert_allclose(y, x)


def test_grad_tap_stochastic_rounding_unbiased():
    x = jnp.full((4,), 0.31)
    acc = np.zeros(4)
    n = 120
    for seed in range(n):
        ctx = make_ctx([[0.0, 1.0]], mode_grad=qo.MODE_HINDSIGHT, seed=seed,
                       cfg=qo.QuantConfig(bits_g=3, use_pallas="none"))
        gx, _ = jax.grad(tap_loss(0, ctx), argnums=(0, 1))(x, jnp.zeros((2, 2)))
        acc += np.asarray(gx)
    np.testing.assert_allclose(acc / n, np.asarray(x), atol=0.04)


# ---------------------------------------------------------------------------
# dump_tap: DSGC's raw-gradient channel
# ---------------------------------------------------------------------------

def test_dump_tap_returns_raw_gradient():
    x = jax.random.normal(jax.random.PRNGKey(3), (5, 4)) * 7
    ctx = make_ctx([[-1.0, 1.0]], mode_grad=qo.MODE_HINDSIGHT)

    def f(x, dummy):
        y = qo.dump_tap(x, dummy, 0, ctx)
        return 0.5 * jnp.sum(y * y)

    gx, gd = jax.grad(f, argnums=(0, 1))(x, jnp.zeros_like(x))
    np.testing.assert_allclose(gd, x, rtol=1e-6)  # raw (pre-quant) gradient
    assert float(jnp.abs(gx).max()) <= 1.01  # propagated path quantized


# ---------------------------------------------------------------------------
# pallas/jnp path equivalence inside the ops
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", [0, 1, 2])
def test_act_quant_pallas_matches_jnp(mode):
    x = jax.random.normal(jax.random.PRNGKey(4), (32, 16)) * 2
    for cfg in (CFG, CFG_PALLAS):
        ctx = make_ctx([[-2.0, 2.0]], mode_act=mode, cfg=cfg)
        y, s, r = qo.act_quant(x, 0, ctx)
        if cfg is CFG:
            y0, s0, r0 = y, s, r
    np.testing.assert_allclose(y, y0, atol=1e-5)
    np.testing.assert_allclose(s, s0, rtol=1e-6)
    np.testing.assert_allclose(r, r0, rtol=1e-6)

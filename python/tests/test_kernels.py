"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

hypothesis sweeps shapes, bit-widths and rounding modes; fixed-seed cases
pin the invariants (grid membership, zero-representability, stats).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import fake_quant as fq
from compile.kernels import qmatmul as qm
from compile.kernels import ref

TOL = dict(rtol=1e-5, atol=1e-5)


def _rand(key, shape, scale=3.0):
    return jax.random.normal(jax.random.PRNGKey(key), shape) * scale


# ---------------------------------------------------------------------------
# fake_quant kernel vs oracle
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(
    rows=st.integers(1, 300),
    cols=st.integers(1, 65),
    bits=st.sampled_from([2, 3, 4, 6, 8]),
    qmin=st.floats(-10.0, 0.5),
    width=st.floats(0.1, 12.0),
    seed=st.integers(0, 2**16),
)
def test_fake_quant_matches_ref_nearest(rows, cols, bits, qmin, width, seed):
    x = _rand(seed, (rows, cols))
    r = jnp.array([qmin, qmin + width], jnp.float32)
    xq, stats = fq.fake_quant_with_stats(x, r, bits=bits, block_rows=64)
    xq_ref, stats_ref = ref.fake_quant_with_stats(x, r, bits=bits)
    np.testing.assert_allclose(xq, xq_ref, **TOL)
    np.testing.assert_allclose(stats, stats_ref, **TOL)


@settings(max_examples=6, deadline=None)
@given(
    rows=st.integers(1, 200),
    cols=st.integers(1, 33),
    bits=st.sampled_from([4, 8]),
    seed=st.integers(0, 2**16),
)
def test_fake_quant_matches_ref_stochastic(rows, cols, bits, seed):
    x = _rand(seed, (rows, cols))
    noise = jax.random.uniform(jax.random.PRNGKey(seed + 1), x.shape)
    r = jnp.array([-4.0, 5.0], jnp.float32)
    xq, _ = fq.fake_quant_with_stats(x, r, noise, bits=bits, block_rows=64)
    xq_ref, _ = ref.fake_quant_with_stats(x, r, bits=bits, noise=noise)
    np.testing.assert_allclose(xq, xq_ref, **TOL)


@settings(max_examples=6, deadline=None)
@given(
    bits=st.sampled_from([2, 4, 8]),
    qmin=st.floats(-8.0, -0.1),
    width=st.floats(0.2, 16.0),
    seed=st.integers(0, 2**16),
)
def test_output_lies_on_grid(bits, qmin, width, seed):
    """Every quantized value must be one of the 2**bits grid points."""
    x = _rand(seed, (64, 17), scale=6.0)
    r = jnp.array([qmin, qmin + width], jnp.float32)
    xq, _ = fq.fake_quant_with_stats(x, r, bits=bits)
    scale, zp, n = ref.quant_params(r[0], r[1], bits)
    idx = np.asarray(xq) / float(scale) + float(zp)
    np.testing.assert_allclose(idx, np.round(idx), atol=1e-3)
    assert idx.min() >= -1e-3 and idx.max() <= n + 1e-3


def test_zero_is_exactly_representable():
    """Asymmetric grid must contain 0 exactly (padding/ReLU correctness)."""
    x = jnp.zeros((8, 8))
    for r in ([-3.0, 5.0], [0.5, 2.0], [-4.0, -1.0]):
        xq, _ = fq.fake_quant_with_stats(x, jnp.array(r, jnp.float32))
        assert float(jnp.abs(xq).max()) == 0.0


def test_saturation_clips_to_range_edges():
    x = jnp.array([[-100.0, 100.0, 0.0, 1.0]])
    r = jnp.array([-2.0, 2.0], jnp.float32)
    xq, stats = fq.fake_quant_with_stats(x, r, bits=8)
    # grid edges are zero-point-rounded: (0 - zp)*scale and (n - zp)*scale
    scale, zp, n = ref.quant_params(r[0], r[1], 8)
    lo, hi = float((0 - zp) * scale), float((n - zp) * scale)
    assert float(xq[0, 0]) == pytest.approx(lo, abs=1e-5)
    assert float(xq[0, 1]) == pytest.approx(hi, abs=1e-5)
    # stats still report the *unquantized* extrema (accumulator view)
    np.testing.assert_allclose(stats, [-100.0, 100.0], rtol=1e-6)


def test_degenerate_range_is_safe():
    """All-zero range must not produce NaN/Inf (EPS_SCALE guard)."""
    x = _rand(0, (16, 16))
    xq, _ = fq.fake_quant_with_stats(x, jnp.zeros(2, jnp.float32))
    assert bool(jnp.all(jnp.isfinite(xq)))


def test_stochastic_rounding_is_unbiased():
    """E[Q(x)] ≈ x over noise draws (Gupta et al. 2015 property)."""
    x = jnp.full((4, 4), 0.3)
    r = jnp.array([0.0, 1.0], jnp.float32)
    acc = np.zeros((4, 4))
    n = 400
    for i in range(n):
        noise = jax.random.uniform(jax.random.PRNGKey(i), x.shape)
        xq, _ = fq.fake_quant_with_stats(x, r, noise, bits=2)
        acc += np.asarray(xq)
    np.testing.assert_allclose(acc / n, np.asarray(x), atol=0.02)


def test_1d_and_4d_shapes():
    r = jnp.array([-1.0, 1.0], jnp.float32)
    for shape in [(7,), (2, 3, 4, 5), (1, 1), (513,)]:
        x = _rand(3, shape, scale=1.0)
        xq, stats = fq.fake_quant_with_stats(x, r)
        xq_ref, stats_ref = ref.fake_quant_with_stats(x, r)
        np.testing.assert_allclose(xq, xq_ref, **TOL)
        np.testing.assert_allclose(stats, stats_ref, **TOL)
        assert xq.shape == shape


# ---------------------------------------------------------------------------
# qmatmul kernel vs oracle
# ---------------------------------------------------------------------------

@settings(max_examples=8, deadline=None)
@given(
    m=st.integers(1, 150),
    k=st.integers(1, 150),
    n=st.integers(1, 150),
    bits=st.sampled_from([4, 8]),
    seed=st.integers(0, 2**16),
)
def test_qmatmul_matches_ref(m, k, n, bits, seed):
    a = _rand(seed, (m, k), scale=1.0)
    b = _rand(seed + 1, (k, n), scale=1.0)
    r = jnp.array([-float(k), float(k)], jnp.float32) / 3.0
    yq, stats = qm.qmatmul(a, b, r, bits=bits, bm=64, bn=64, bk=64)
    yq_ref, _ = ref.qmatmul(a, b, r, bits=bits)
    # ULP noise in the scale/zero-point computation can flip round-half
    # ties, shifting individual values by exactly one grid step — allow it.
    scale, _, _ = ref.quant_params(r[0], r[1], bits)
    assert float(jnp.abs(yq - yq_ref).max()) <= float(scale) * 1.001
    # stats: padding folds exact zeros, grid always contains 0, so compare
    # against the zero-widened oracle extrema.
    y = jnp.matmul(a, b)
    np.testing.assert_allclose(stats[0], min(float(y.min()), 0.0), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(stats[1], max(float(y.max()), 0.0), rtol=1e-4, atol=1e-4)


def test_qmatmul_multi_tile_accumulation():
    """K larger than bk exercises the revisited-accumulator path."""
    a = _rand(10, (96, 300), scale=0.5)
    b = _rand(11, (300, 64), scale=0.5)
    r = jnp.array([-40.0, 40.0], jnp.float32)
    yq, _ = qm.qmatmul(a, b, r, bits=8, bm=32, bn=32, bk=64)
    yq_ref, _ = ref.qmatmul(a, b, r, bits=8)
    np.testing.assert_allclose(yq, yq_ref, rtol=1e-4, atol=1e-4)


def test_qmatmul_identity_roundtrip():
    """A @ I with a wide range ≈ A up to one quantization step."""
    a = _rand(12, (32, 32), scale=1.0)
    eye = jnp.eye(32)
    r = jnp.array([-6.0, 6.0], jnp.float32)
    yq, _ = qm.qmatmul(a, eye, r, bits=8)
    step = 12.0 / 255.0
    assert float(jnp.abs(yq - a).max()) <= step


# ---------------------------------------------------------------------------
# structural §Perf estimators
# ---------------------------------------------------------------------------

def test_vmem_budgets():
    assert qm.vmem_bytes() < 16 * 2**20
    assert fq.vmem_bytes((1024, 1024)) < 16 * 2**20


def test_mxu_utilization_estimate_bounds():
    u = qm.mxu_utilization_estimate(128, 128, 128)
    assert u == pytest.approx(1.0)
    u2 = qm.mxu_utilization_estimate(129, 129, 129)
    assert 0.0 < u2 < 1.0


# ---------------------------------------------------------------------------
# oracle self-consistency (ema, saturation)
# ---------------------------------------------------------------------------

def test_ema_update_matches_paper_eqs23():
    prev = jnp.array([-1.0, 2.0])
    stats = jnp.array([-3.0, 1.0])
    out = ref.ema_update(prev, stats, 0.9)
    np.testing.assert_allclose(out, [0.9 * -1.0 + 0.1 * -3.0,
                                     0.9 * 2.0 + 0.1 * 1.0], rtol=1e-6)


def test_saturation_ratio():
    x = jnp.array([-2.0, -0.5, 0.5, 3.0])
    assert float(ref.saturation_ratio(x, -1.0, 1.0)) == pytest.approx(0.5)

"""Manifest/artifact invariants: the Rust ABI contract, checked from the
Python side (fast — no tracing, no jit)."""

import json
import os

import pytest

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")

pytestmark = pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)


@pytest.fixture(scope="module")
def manifest():
    with open(os.path.join(ART, "manifest.json")) as f:
        return json.load(f)


def test_all_hlo_files_exist(manifest):
    for name, m in manifest["models"].items():
        for gname, g in m["graphs"].items():
            path = os.path.join(ART, g["file"])
            assert os.path.exists(path), f"{name}/{gname}: missing {g['file']}"
            assert os.path.getsize(path) > 1000


def test_site_indices_are_dense_and_ordered(manifest):
    for name, m in manifest["models"].items():
        idx = [s["index"] for s in m["sites"]]
        assert idx == list(range(len(idx))), name
        kinds = {s["kind"] for s in m["sites"]}
        assert kinds <= {"act", "grad"}


def test_train_graph_abi_shape(manifest):
    """inputs = 2P + S + (x, y, ranges) + 9 scalars; outputs = 2P + S + 4."""
    for name, m in manifest["models"].items():
        if "train" not in m["graphs"]:
            continue
        g = m["graphs"]["train"]
        P, S, Q = len(m["params"]), len(m["state"]), len(m["sites"])
        assert len(g["inputs"]) == 2 * P + S + 3 + 9, name
        assert len(g["outputs"]) == 2 * P + S + 4, name
        names = [io["name"] for io in g["inputs"]]
        ranges = g["inputs"][names.index("ranges")]
        assert ranges["shape"] == [Q, 2], name
        stats = g["outputs"][-1]
        assert stats["name"] == "stats" and stats["shape"] == [Q, 2], name
        # x matches batch/input_shape, y is i32
        x = g["inputs"][names.index("x")]
        assert x["shape"] == [m["batch_size"]] + m["input_shape"], name
        y = g["inputs"][names.index("y")]
        assert y["dtype"] == "i32", name


def test_dump_graph_matches_grad_sites(manifest):
    for name, m in manifest["models"].items():
        if "dump" not in m["graphs"]:
            continue
        g = m["graphs"]["dump"]
        gsites = [s for s in m["sites"] if s["kind"] == "grad"]
        assert len(g["outputs"]) == len(gsites), name
        for out, site in zip(g["outputs"], gsites):
            assert out["shape"] == [m["batch_size"]] + site["feature_shape"], (
                name, site["name"])


def test_param_shapes_consistent_between_init_and_train(manifest):
    for name, m in manifest["models"].items():
        if "train" not in m["graphs"] or "init" not in m["graphs"]:
            continue
        init_out = m["graphs"]["init"]["outputs"]
        train_in = m["graphs"]["train"]["inputs"]
        n_carry = len(init_out)
        for a, b in zip(init_out, train_in[:n_carry]):
            assert a["name"] == b["name"], name
            assert a["shape"] == b["shape"], (name, a["name"])


def test_quant_spec_is_paper_w8a8g8(manifest):
    q = manifest["quant"]
    assert (q["bits_w"], q["bits_a"], q["bits_g"]) == (8, 8, 8)


def test_pallas_placement_matrix(manifest):
    """The quickstart/e2e artifacts carry the Pallas kernel; the table
    sweep artifacts use the oracle lowering (DESIGN.md §3)."""
    m = manifest["models"]
    assert m["mlp"]["pallas"] == "all"
    assert m["cnn"]["pallas"] == "all"
    assert m["resnet_pallas"]["pallas"] == "grad"
    for name in ("resnet_tiny", "vgg_tiny", "mobilenet_tiny"):
        assert m[name]["pallas"] == "none"
